//! The open-loop serving-gateway workload program:
//! `serve::gateway::run_gateway`'s admission/batching loop as a steppable
//! [`Workload`].
//!
//! The gateway is a discrete-event loop over three event kinds — request
//! arrivals, batch-wait deadlines, and autoscale window boundaries — fired
//! in virtual-time order with the same tie-breaking the standalone loop
//! always used (a due deadline fires before the arrival that exposes it;
//! deadlines beat window boundaries on ties). [`Workload::step`] simply
//! processes every event before the horizon, so partitioning a run into
//! scheduling rounds reproduces the identical event sequence — and
//! bit-identical metrics — as one infinite-horizon pass.
//!
//! Two dispatch-flush policies share this one implementation:
//!
//! * **max-wait** ([`GatewayProgram::new`]) — the standalone gateway's
//!   dynamic batching: a partial batch dispatches when its oldest request
//!   has waited [`GatewayConfig::max_wait_s`].
//! * **round-flush** ([`GatewayProgram::round_flush`]) — the multi-tenant
//!   scheduler's historical policy for `sched::JobKind::Serving` tenants:
//!   partial batches flush at the scheduling-round boundary (the step
//!   horizon) instead.
//!
//! Three week-scale mechanisms live here too, each bit-identical to the
//! exact path when disabled:
//!
//! * **Streaming arrivals** — the program consumes a [`TraceSource`]
//!   cursor, so a lazily generated week-long trace is never materialized;
//!   a wrapped `Arc<[Request]>` replays the classic path unchanged.
//! * **Macro-request aggregation** ([`GatewayConfig::aggregation`]) — `K`
//!   consecutive admitted arrivals coalesce into one macro-request. A
//!   dispatch takes up to `max_batch` *macros*, charging the fabric hops
//!   and `PolicyFwd` once at the aggregate request count, while each
//!   member request's latency still runs from its own arrival to the
//!   shared completion. `K = 1` closes every macro on arrival and replays
//!   today's per-request path bit-for-bit.
//! * **Bounded samples** ([`GatewayConfig::sample_cap`]) — latency
//!   accumulation runs through seeded [`SampleReservoir`]s (exact below
//!   the cap) and the diagnostic ledgers stop growing at the cap, so
//!   memory stays O(cap) over a 10^7-request day.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use anyhow::Result;

use super::{StepCtx, StepOutcome, Workload};
use crate::config::BenchInfo;
use crate::engine::{Engine, ExecutorId};
use crate::fabric::Fabric;
use crate::gmi::Role;
use crate::metrics::{percentile_select, LatencyStats, RunMetrics, SampleReservoir};
use crate::serve::autoscale::{Autoscaler, ScaleEvent};
use crate::serve::gateway::{
    execute_dispatch_pooled, least_loaded, DispatchPlans, GatewayConfig, ServedRequest,
};
use crate::serve::{Request, TraceSource};

/// Seed for the final latency reservoir (only drawn from once the sample
/// cap is exceeded); fixed so every run replays bit-identically.
const FINAL_LAT_SEED: u64 = 0x9A7E_11A7_5EED_0001;
/// Seed for the per-window latency reservoir.
const WINDOW_LAT_SEED: u64 = 0x9A7E_11A7_5EED_0002;

/// One closed macro-request waiting in the batching queue: `count`
/// consecutive admitted requests (their payloads sit in order on the flat
/// request queue), plus the wait-deadline anchor of its oldest member.
/// Plain `Copy` data — no per-macro allocation on the dispatch hot path.
#[derive(Debug, Clone, Copy)]
struct MacroEntry {
    count: usize,
    /// Arrival of the macro's FIRST member: the max-wait anchor.
    anchor_s: f64,
}

/// Steppable open-loop gateway program (see module docs).
pub struct GatewayProgram {
    cfg: GatewayConfig,
    /// Arrival cursor: either a shared materialized trace or the lazy
    /// seeded generator. Cloned wholesale by `snapshot`, so a restored
    /// tenant resumes mid-stream.
    source: TraceSource,
    /// Flush partial batches at the step horizon (the scheduler's round
    /// boundary) instead of at per-request wait deadlines.
    flush_at_horizon: bool,
    // ---- bound membership ----
    /// The live fleet dispatches target (replaced by `bind`, extended by
    /// the standalone autoscaler).
    active: Vec<ExecutorId>,
    /// Every executor that was ever a member (span accounting).
    all_members: Vec<ExecutorId>,
    dedicated: bool,
    bound: bool,
    start_s: f64,
    // ---- run state ----
    /// Arrivals consumed from the source so far (admitted + rejected).
    arrivals_seen: usize,
    /// Admitted requests in queue order, flattened across macros.
    pending_reqs: VecDeque<Request>,
    /// Closed macro-requests over the head of `pending_reqs`.
    pending_macros: VecDeque<MacroEntry>,
    /// Members accumulated into the still-open macro (the tail of
    /// `pending_reqs` not yet covered by `pending_macros`).
    open_count: usize,
    open_anchor_s: f64,
    served: Vec<ServedRequest>,
    batch_sizes: Vec<usize>,
    /// Running dispatch counters (exact even when the ledgers are capped).
    served_count: usize,
    slo_hits: usize,
    dispatch_count: usize,
    dispatched_reqs: usize,
    rejected: usize,
    /// Admitted and not yet completed (queued + in-flight).
    outstanding: usize,
    max_queue_depth: usize,
    /// In-flight dispatches as (completion bits, request count): bit
    /// patterns of non-negative finite times order like the values
    /// (min-heap via Reverse), and one entry covers the whole batch.
    completions: BinaryHeap<Reverse<(u64, usize)>>,
    /// End-to-end latency of every served request, dispatch order; exact
    /// until `cfg.sample_cap`, seeded reservoir beyond it.
    final_lat: SampleReservoir,
    // ---- SLO / autoscale signals ----
    scaler: Option<Autoscaler>,
    scale_events: Vec<ScaleEvent>,
    next_window: f64,
    /// Latencies dispatched in the current autoscale window (None without
    /// an autoscaler).
    window_lat: Option<SampleReservoir>,
    /// Latencies dispatched during the current step (the scheduler's
    /// per-round SLO pressure signal).
    step_lat: Vec<f64>,
    last_p99: Option<f64>,
    /// Pooled request/response transfer-plan buffers, rewritten in place
    /// on every dispatch.
    plans: DispatchPlans,
}

impl GatewayProgram {
    /// Standalone dynamic-batching gateway (max-wait flush).
    pub fn new(cfg: GatewayConfig, trace: impl Into<TraceSource>) -> Self {
        let final_lat = match cfg.sample_cap {
            Some(cap) => SampleReservoir::capped(cap, FINAL_LAT_SEED),
            None => SampleReservoir::unbounded(),
        };
        GatewayProgram {
            cfg,
            source: trace.into(),
            flush_at_horizon: false,
            active: Vec::new(),
            all_members: Vec::new(),
            dedicated: false,
            bound: false,
            start_s: 0.0,
            arrivals_seen: 0,
            pending_reqs: VecDeque::new(),
            pending_macros: VecDeque::new(),
            open_count: 0,
            open_anchor_s: 0.0,
            served: Vec::new(),
            batch_sizes: Vec::new(),
            served_count: 0,
            slo_hits: 0,
            dispatch_count: 0,
            dispatched_reqs: 0,
            rejected: 0,
            outstanding: 0,
            max_queue_depth: 0,
            completions: BinaryHeap::new(),
            final_lat,
            scaler: None,
            scale_events: Vec::new(),
            next_window: f64::INFINITY,
            window_lat: None,
            step_lat: Vec::new(),
            last_p99: None,
            plans: DispatchPlans::default(),
        }
    }

    /// Scheduler-tenant variant: partial batches flush at each step's
    /// horizon (the scheduling-round boundary) and wait deadlines are
    /// disabled.
    pub fn round_flush(mut cfg: GatewayConfig, trace: impl Into<TraceSource>) -> Self {
        cfg.max_wait_s = f64::INFINITY;
        let mut p = GatewayProgram::new(cfg, trace);
        p.flush_at_horizon = true;
        p
    }

    /// Admitted requests in dispatch order; consumes the log. Truncated at
    /// `cfg.sample_cap` entries when a cap is set (the running counters
    /// and reservoirs stay exact).
    pub fn take_served(&mut self) -> Vec<ServedRequest> {
        std::mem::take(&mut self.served)
    }

    /// Size of every dispatched batch, in dispatch order; consumes the
    /// log. Truncated at `cfg.sample_cap` entries when a cap is set.
    pub fn take_batch_sizes(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.batch_sizes)
    }

    /// Applied autoscale steps; consumes the log.
    pub fn take_scale_events(&mut self) -> Vec<ScaleEvent> {
        std::mem::take(&mut self.scale_events)
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// Capacities of the per-run reusable hot-path buffers, in a fixed
    /// order: pending request queue, in-flight completion heap, per-step
    /// latency scratch, autoscale window scratch, pooled request plan
    /// steps, pooled response plan steps. The no-realloc regression test
    /// snapshots these after warmup and asserts the steady state never
    /// regrows them.
    #[doc(hidden)]
    pub fn hot_buffer_caps(&self) -> [usize; 6] {
        let (req, resp) = self.plans.step_caps();
        [
            self.pending_reqs.capacity(),
            self.completions.capacity(),
            self.step_lat.capacity(),
            self.window_lat.as_ref().map_or(0, |w| w.capacity()),
            req,
            resp,
        ]
    }

    /// Whether the ledgers (`served`, `batch_sizes`) may still grow.
    fn ledger_open(&self, len: usize) -> bool {
        match self.cfg.sample_cap {
            Some(cap) => len < cap,
            None => true,
        }
    }

    /// Close the open partial macro (if any) into the dispatchable queue.
    fn close_open(&mut self) {
        if self.open_count > 0 {
            self.pending_macros
                .push_back(MacroEntry { count: self.open_count, anchor_s: self.open_anchor_s });
            self.open_count = 0;
        }
    }

    /// Dispatch up to `max_batch` queued macro-requests at virtual time
    /// `t` onto the least-loaded active member as engine events (request
    /// hop, `PolicyFwd`, response hop — each charged ONCE at the aggregate
    /// request count).
    fn dispatch(&mut self, ctx: &mut StepCtx<'_>, t: f64) {
        let n_macros = self.pending_macros.len().min(self.cfg.max_batch);
        if n_macros == 0 {
            return;
        }
        let mut n = 0usize;
        for _ in 0..n_macros {
            n += self.pending_macros.pop_front().expect("macro under-run").count;
        }
        let ex = least_loaded(ctx.engine, &self.active);
        let batch_idx = self.dispatch_count;
        let done = execute_dispatch_pooled(
            ctx.engine,
            ctx.fabric,
            ctx.cost,
            ctx.bench,
            ex,
            t,
            n,
            self.dedicated,
            &mut self.plans,
        );
        let done_s = done.seconds();
        for _ in 0..n {
            let r = self.pending_reqs.pop_front().expect("batch under-run");
            if self.ledger_open(self.served.len()) {
                self.served.push(ServedRequest {
                    id: r.id,
                    source: r.source,
                    arrival_s: r.arrival_s,
                    batch: batch_idx,
                    dispatch_s: t,
                    completion_s: done_s,
                });
            }
            let lat = done_s - r.arrival_s;
            self.served_count += 1;
            if lat <= self.cfg.slo_s + 1e-12 {
                self.slo_hits += 1;
            }
            self.final_lat.push(lat);
            if let Some(w) = self.window_lat.as_mut() {
                w.push(lat);
            }
            self.step_lat.push(lat);
        }
        // One heap entry per dispatch, not per request: retiring pops the
        // whole batch at once (identical `outstanding` trajectory).
        self.completions.push(Reverse((done_s.to_bits(), n)));
        if self.ledger_open(self.batch_sizes.len()) {
            self.batch_sizes.push(n);
        }
        self.dispatch_count += 1;
        self.dispatched_reqs += n;
    }

    /// Process one arrival: retire due completions, apply admission
    /// control, accumulate into the open macro, and dispatch a full batch
    /// immediately.
    fn arrive(&mut self, ctx: &mut StepCtx<'_>, r: Request) {
        let t = r.arrival_s;
        while let Some(&Reverse((bits, cnt))) = self.completions.peek() {
            if f64::from_bits(bits) <= t {
                self.completions.pop();
                self.outstanding -= cnt;
            } else {
                break;
            }
        }
        if self.cfg.admission_cap.is_some_and(|cap| self.outstanding >= cap) {
            self.rejected += 1;
            return;
        }
        self.outstanding += 1;
        self.max_queue_depth = self.max_queue_depth.max(self.outstanding);
        if self.open_count == 0 {
            self.open_anchor_s = t;
        }
        self.pending_reqs.push_back(r);
        self.open_count += 1;
        if self.open_count >= self.cfg.aggregation.max(1) {
            self.close_open();
        }
        if self.pending_macros.len() >= self.cfg.max_batch {
            self.dispatch(ctx, t);
        }
    }

    /// Wait-deadline of the oldest queued request: the front closed macro
    /// if any, otherwise the open partial one.
    fn oldest_anchor(&self) -> Option<f64> {
        match self.pending_macros.front() {
            Some(m) => Some(m.anchor_s),
            None if self.open_count > 0 => Some(self.open_anchor_s),
            None => None,
        }
    }
}

impl Workload for GatewayProgram {
    fn bind(
        &mut self,
        engine: &Engine,
        _fabric: &mut Fabric,
        _bench: &BenchInfo,
        members: &[ExecutorId],
    ) -> Result<()> {
        anyhow::ensure!(!members.is_empty(), "no serving GMIs in fleet");
        anyhow::ensure!(self.cfg.max_batch >= 1, "max_batch must be at least 1");
        anyhow::ensure!(self.cfg.aggregation >= 1, "aggregation must be at least 1");
        anyhow::ensure!(self.cfg.max_wait_s >= 0.0, "max_wait_s must be non-negative");
        // An infinite wait means partial batches NEVER flush under the
        // max-wait policy: the end-of-trace drain would spin forever. Only
        // the round-flush variant (which flushes at the step horizon
        // instead) may disable wait deadlines.
        anyhow::ensure!(
            self.flush_at_horizon || self.cfg.max_wait_s.is_finite(),
            "max_wait_s must be finite under the max-wait flush policy"
        );
        if !self.bound {
            self.bound = true;
            self.start_s = engine.max_time(members).seconds();
            // TDG fleets (dedicated simulator/agent GMIs) pay the
            // reduced-share forward of the rejected design.
            self.dedicated = members.iter().any(|&ex| {
                engine
                    .manager()
                    .gmi(engine.gmi_of(ex))
                    .is_some_and(|g| matches!(g.role, Role::Simulator | Role::Agent))
            });
            if let Some(a) = self.cfg.autoscale {
                let scaler = Autoscaler::new(a, engine, members)?;
                self.next_window = scaler.window_s();
                self.window_lat = Some(match self.cfg.sample_cap {
                    Some(cap) => SampleReservoir::capped(cap, WINDOW_LAT_SEED),
                    None => SampleReservoir::unbounded(),
                });
                self.scaler = Some(scaler);
            }
        }
        // A changed fleet invalidates the pooled dispatch plans: a
        // shrunken fleet's buffers may hold hops over a departed (possibly
        // failed) GPU's host path, and the single-hop reuse fast path
        // would replay them. Unchanged-membership rebinds (the steady
        // state) keep the buffers — and their capacity — untouched.
        if self.active.as_slice() != members {
            self.plans.clear();
        }
        // Rebinding (the scheduler re-places tenants every round) reuses
        // the membership buffer's capacity instead of reallocating.
        self.active.clear();
        self.active.extend_from_slice(members);
        for &ex in members {
            if !self.all_members.contains(&ex) {
                self.all_members.push(ex);
            }
        }
        Ok(())
    }

    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepOutcome> {
        anyhow::ensure!(self.bound, "gateway program stepped before bind");
        self.step_lat.clear();
        let h = ctx.horizon_s;
        loop {
            let t_arr = self.source.peek_arrival_s().unwrap_or(f64::INFINITY);
            let arrivals_left = t_arr.is_finite();
            let deadline = match self.oldest_anchor() {
                Some(a) => a + self.cfg.max_wait_s,
                None => f64::INFINITY,
            };
            // Windows only tick while arrivals remain (the standalone
            // drain after the last arrival never re-evaluates the scaler).
            let window = if arrivals_left && self.scaler.is_some() {
                self.next_window
            } else {
                f64::INFINITY
            };
            if deadline <= t_arr && deadline <= window {
                if deadline >= h {
                    break;
                }
                // A deadline with no closed macro is the open partial one
                // timing out: seal it so it rides this dispatch.
                if self.pending_macros.is_empty() {
                    self.close_open();
                }
                self.dispatch(ctx, deadline);
            } else if window <= t_arr {
                if window >= h {
                    break;
                }
                let w = window;
                if let Some(s) = self.scaler.as_mut() {
                    let lat =
                        self.window_lat.as_ref().map(|r| r.samples()).unwrap_or(&[]);
                    if let Some(ev) = s.evaluate(w, ctx.engine, &mut self.active, lat) {
                        self.scale_events.push(ev);
                    }
                }
                if let Some(wl) = self.window_lat.as_mut() {
                    wl.clear();
                }
                self.next_window =
                    w + self.scaler.as_ref().map(|s| s.window_s()).unwrap_or(f64::INFINITY);
                for &ex in &self.active {
                    if !self.all_members.contains(&ex) {
                        self.all_members.push(ex);
                    }
                }
            } else if arrivals_left {
                if t_arr >= h {
                    break;
                }
                let r = self.source.next().expect("peeked arrival vanished");
                self.arrivals_seen += 1;
                self.arrive(ctx, r);
            } else {
                break;
            }
        }
        if self.flush_at_horizon && h.is_finite() {
            self.close_open();
            while !self.pending_macros.is_empty() {
                self.dispatch(ctx, h);
            }
        }
        self.last_p99 = if self.step_lat.is_empty() {
            None
        } else {
            // Selected in place (the scratch is cleared at the next step
            // anyway): no per-round clone + sort. `percentile_select` is
            // bit-identical to nearest-rank over a sorted copy.
            Some(percentile_select(&mut self.step_lat, 0.99))
        };
        if self.source.peek().is_none()
            && self.pending_macros.is_empty()
            && self.open_count == 0
        {
            return Ok(StepOutcome::Done);
        }
        Ok(StepOutcome::Pending)
    }

    fn slo_signal(&self) -> Option<f64> {
        self.last_p99
    }

    fn next_event_hint(&mut self) -> Option<f64> {
        if !self.bound {
            return None;
        }
        // The round after a dispatching one must run: it decays
        // `slo_signal` to None exactly as the naive loop observes it.
        if self.last_p99.is_some() {
            return None;
        }
        let next_arr = self.source.peek_arrival_s();
        let queued = !self.pending_macros.is_empty() || self.open_count > 0;
        // Drained stream: the next step reports Done — let it run.
        if next_arr.is_none() && !queued {
            return None;
        }
        // Round-flush tenants flush queued work at every horizon.
        if self.flush_at_horizon && queued {
            return None;
        }
        let mut t = next_arr.unwrap_or(f64::INFINITY);
        if !self.flush_at_horizon {
            if let Some(a) = self.oldest_anchor() {
                t = t.min(a + self.cfg.max_wait_s);
            }
        }
        if next_arr.is_some() && self.scaler.is_some() {
            t = t.min(self.next_window);
        }
        t.is_finite().then_some(t)
    }

    fn snapshot(&self) -> Option<Box<dyn Workload>> {
        // Trace cursor, served/latency logs, and admission state survive;
        // the fleet, pooled dispatch plans, and autoscaler state do not —
        // the restore placement rebinds a fresh fleet. `bound`/`start_s`
        // carry over so the resumed program keeps its original span
        // accounting. Queued and in-flight requests ride along (their
        // payloads and completion clocks are placement-independent global
        // virtual times).
        Some(Box::new(GatewayProgram {
            cfg: self.cfg,
            source: self.source.clone(),
            flush_at_horizon: self.flush_at_horizon,
            active: Vec::new(),
            all_members: self.all_members.clone(),
            dedicated: self.dedicated,
            bound: self.bound,
            start_s: self.start_s,
            arrivals_seen: self.arrivals_seen,
            pending_reqs: self.pending_reqs.clone(),
            pending_macros: self.pending_macros.clone(),
            open_count: self.open_count,
            open_anchor_s: self.open_anchor_s,
            served: self.served.clone(),
            batch_sizes: self.batch_sizes.clone(),
            served_count: self.served_count,
            slo_hits: self.slo_hits,
            dispatch_count: self.dispatch_count,
            dispatched_reqs: self.dispatched_reqs,
            rejected: self.rejected,
            outstanding: self.outstanding,
            max_queue_depth: self.max_queue_depth,
            completions: self.completions.clone(),
            final_lat: self.final_lat.clone(),
            scaler: None,
            scale_events: self.scale_events.clone(),
            next_window: f64::INFINITY,
            window_lat: None,
            step_lat: Vec::new(),
            last_p99: None,
            plans: DispatchPlans::default(),
        }))
    }

    fn finish(&mut self, engine: &Engine, fabric: &Fabric) -> RunMetrics {
        // `requests` counts the whole trace: consumed arrivals plus (for a
        // materialized backing) whatever remains unconsumed. A streaming
        // source reports what it has actually emitted.
        let total = self.arrivals_seen + self.source.len_hint().unwrap_or(0);
        let served_n = self.served_count;
        // Mean over dispatch order from the reservoir's running sum — the
        // identical fold the exact path computed, taken BEFORE the
        // selections below permute the sample buffer.
        let mean_s = if served_n > 0 { self.final_lat.sum() / served_n as f64 } else { 0.0 };
        let mean_batch = if self.dispatch_count == 0 {
            0.0
        } else {
            self.dispatched_reqs as f64 / self.dispatch_count as f64
        };
        let lats = self.final_lat.samples_mut();
        let latency = LatencyStats {
            requests: total,
            served: served_n,
            rejected: self.rejected,
            p50_s: percentile_select(lats, 0.50),
            p95_s: percentile_select(lats, 0.95),
            p99_s: percentile_select(lats, 0.99),
            mean_s,
            slo_s: self.cfg.slo_s,
            attainment: if total > 0 { self.slo_hits as f64 / total as f64 } else { 1.0 },
            mean_batch,
            max_queue_depth: self.max_queue_depth,
        };
        let span = engine.max_time(&self.all_members).seconds() - self.start_s;
        let peak_mem = self
            .active
            .iter()
            .filter_map(|&ex| engine.manager().gmi(engine.gmi_of(ex)))
            .map(|g| g.mem_gib)
            .fold(0.0f64, f64::max);
        RunMetrics {
            steps_per_sec: if span > 0.0 { served_n as f64 / span } else { 0.0 },
            pps: if span > 0.0 { served_n as f64 / span } else { 0.0 },
            ttop: 0.0,
            span_s: span,
            utilization: engine.mean_utilization(),
            final_reward: 0.0,
            reward_curve: vec![],
            comm_s: super::scoped_comm_s(engine, &self.all_members),
            peak_mem_gib: peak_mem,
            links: fabric.link_report(),
            latency: Some(latency),
            replay: None,
        }
    }
}
