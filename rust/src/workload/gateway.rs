//! The open-loop serving-gateway workload program:
//! `serve::gateway::run_gateway`'s admission/batching loop as a steppable
//! [`Workload`].
//!
//! The gateway is a discrete-event loop over three event kinds — request
//! arrivals, batch-wait deadlines, and autoscale window boundaries — fired
//! in virtual-time order with the same tie-breaking the standalone loop
//! always used (a due deadline fires before the arrival that exposes it;
//! deadlines beat window boundaries on ties). [`Workload::step`] simply
//! processes every event before the horizon, so partitioning a run into
//! scheduling rounds reproduces the identical event sequence — and
//! bit-identical metrics — as one infinite-horizon pass.
//!
//! Two dispatch-flush policies share this one implementation:
//!
//! * **max-wait** ([`GatewayProgram::new`]) — the standalone gateway's
//!   dynamic batching: a partial batch dispatches when its oldest request
//!   has waited [`GatewayConfig::max_wait_s`].
//! * **round-flush** ([`GatewayProgram::round_flush`]) — the multi-tenant
//!   scheduler's historical policy for `sched::JobKind::Serving` tenants:
//!   partial batches flush at the scheduling-round boundary (the step
//!   horizon) instead.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;

use anyhow::Result;

use super::{StepCtx, StepOutcome, Workload};
use crate::config::BenchInfo;
use crate::engine::{Engine, ExecutorId};
use crate::fabric::Fabric;
use crate::gmi::Role;
use crate::metrics::{percentile_select, LatencyStats, RunMetrics};
use crate::serve::autoscale::{Autoscaler, ScaleEvent};
use crate::serve::gateway::{
    execute_dispatch_pooled, least_loaded, DispatchPlans, GatewayConfig, ServedRequest,
};
use crate::serve::Request;

/// Steppable open-loop gateway program (see module docs).
pub struct GatewayProgram {
    cfg: GatewayConfig,
    /// Shared, immutable arrival trace: the scheduler's job table and every
    /// program instance borrow one allocation instead of deep-copying the
    /// (potentially multi-million-request) trace per run.
    trace: Arc<[Request]>,
    /// Flush partial batches at the step horizon (the scheduler's round
    /// boundary) instead of at per-request wait deadlines.
    flush_at_horizon: bool,
    // ---- bound membership ----
    /// The live fleet dispatches target (replaced by `bind`, extended by
    /// the standalone autoscaler).
    active: Vec<ExecutorId>,
    /// Every executor that was ever a member (span accounting).
    all_members: Vec<ExecutorId>,
    dedicated: bool,
    bound: bool,
    start_s: f64,
    // ---- run state ----
    next_idx: usize,
    pending: VecDeque<usize>,
    served: Vec<ServedRequest>,
    batch_sizes: Vec<usize>,
    rejected: usize,
    /// Admitted and not yet completed (queued + in-flight).
    outstanding: usize,
    max_queue_depth: usize,
    /// Completion times (bit patterns) of everything in flight.
    completions: BinaryHeap<Reverse<u64>>,
    // ---- SLO / autoscale signals ----
    scaler: Option<Autoscaler>,
    scale_events: Vec<ScaleEvent>,
    next_window: f64,
    /// Latencies dispatched in the current autoscale window (None without
    /// an autoscaler).
    window_lat: Option<Vec<f64>>,
    /// Latencies dispatched during the current step (the scheduler's
    /// per-round SLO pressure signal).
    step_lat: Vec<f64>,
    last_p99: Option<f64>,
    /// Pooled request/response transfer-plan buffers, rewritten in place
    /// on every dispatch.
    plans: DispatchPlans,
}

impl GatewayProgram {
    /// Standalone dynamic-batching gateway (max-wait flush).
    pub fn new(cfg: GatewayConfig, trace: impl Into<Arc<[Request]>>) -> Self {
        GatewayProgram {
            cfg,
            trace: trace.into(),
            flush_at_horizon: false,
            active: Vec::new(),
            all_members: Vec::new(),
            dedicated: false,
            bound: false,
            start_s: 0.0,
            next_idx: 0,
            pending: VecDeque::new(),
            served: Vec::new(),
            batch_sizes: Vec::new(),
            rejected: 0,
            outstanding: 0,
            max_queue_depth: 0,
            completions: BinaryHeap::new(),
            scaler: None,
            scale_events: Vec::new(),
            next_window: f64::INFINITY,
            window_lat: None,
            step_lat: Vec::new(),
            last_p99: None,
            plans: DispatchPlans::default(),
        }
    }

    /// Scheduler-tenant variant: partial batches flush at each step's
    /// horizon (the scheduling-round boundary) and wait deadlines are
    /// disabled.
    pub fn round_flush(mut cfg: GatewayConfig, trace: impl Into<Arc<[Request]>>) -> Self {
        cfg.max_wait_s = f64::INFINITY;
        let mut p = GatewayProgram::new(cfg, trace);
        p.flush_at_horizon = true;
        p
    }

    /// Admitted requests in dispatch order; consumes the log.
    pub fn take_served(&mut self) -> Vec<ServedRequest> {
        std::mem::take(&mut self.served)
    }

    /// Size of every dispatched batch, in dispatch order; consumes the log.
    pub fn take_batch_sizes(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.batch_sizes)
    }

    /// Applied autoscale steps; consumes the log.
    pub fn take_scale_events(&mut self) -> Vec<ScaleEvent> {
        std::mem::take(&mut self.scale_events)
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// Capacities of the per-run reusable hot-path buffers, in a fixed
    /// order: pending queue, in-flight completion heap, per-step latency
    /// scratch, autoscale window scratch, pooled request plan steps,
    /// pooled response plan steps. The no-realloc regression test snapshots
    /// these after warmup and asserts the steady state never regrows them.
    #[doc(hidden)]
    pub fn hot_buffer_caps(&self) -> [usize; 6] {
        let (req, resp) = self.plans.step_caps();
        [
            self.pending.capacity(),
            self.completions.capacity(),
            self.step_lat.capacity(),
            self.window_lat.as_ref().map_or(0, |w| w.capacity()),
            req,
            resp,
        ]
    }

    /// Dispatch up to `max_batch` queued requests at virtual time `t` onto
    /// the least-loaded active member as engine events (request hop,
    /// batched `PolicyFwd`, response hop).
    fn dispatch(&mut self, ctx: &mut StepCtx<'_>, t: f64) {
        let n = self.pending.len().min(self.cfg.max_batch);
        if n == 0 {
            return;
        }
        let ex = least_loaded(ctx.engine, &self.active);
        let batch_idx = self.batch_sizes.len();
        let done = execute_dispatch_pooled(
            ctx.engine,
            ctx.fabric,
            ctx.cost,
            ctx.bench,
            ex,
            t,
            n,
            self.dedicated,
            &mut self.plans,
        );
        let done_s = done.seconds();
        for _ in 0..n {
            let idx = self.pending.pop_front().expect("batch under-run");
            let r = self.trace[idx];
            self.served.push(ServedRequest {
                id: r.id,
                source: r.source,
                arrival_s: r.arrival_s,
                batch: batch_idx,
                dispatch_s: t,
                completion_s: done_s,
            });
            let lat = done_s - r.arrival_s;
            if let Some(w) = self.window_lat.as_mut() {
                w.push(lat);
            }
            self.step_lat.push(lat);
            // Completion times are non-negative finite, so their bit
            // patterns order like the values (min-heap via Reverse).
            self.completions.push(Reverse(done_s.to_bits()));
        }
        self.batch_sizes.push(n);
    }

    /// Process one arrival: retire due completions, apply admission
    /// control, enqueue, and dispatch a full batch immediately.
    fn arrive(&mut self, ctx: &mut StepCtx<'_>, idx: usize) {
        let t = self.trace[idx].arrival_s;
        while let Some(&Reverse(bits)) = self.completions.peek() {
            if f64::from_bits(bits) <= t {
                self.completions.pop();
                self.outstanding -= 1;
            } else {
                break;
            }
        }
        if self.cfg.admission_cap.is_some_and(|cap| self.outstanding >= cap) {
            self.rejected += 1;
            return;
        }
        self.outstanding += 1;
        self.max_queue_depth = self.max_queue_depth.max(self.outstanding);
        self.pending.push_back(idx);
        if self.pending.len() >= self.cfg.max_batch {
            self.dispatch(ctx, t);
        }
    }
}

impl Workload for GatewayProgram {
    fn bind(
        &mut self,
        engine: &Engine,
        _fabric: &mut Fabric,
        _bench: &BenchInfo,
        members: &[ExecutorId],
    ) -> Result<()> {
        anyhow::ensure!(!members.is_empty(), "no serving GMIs in fleet");
        anyhow::ensure!(self.cfg.max_batch >= 1, "max_batch must be at least 1");
        anyhow::ensure!(self.cfg.max_wait_s >= 0.0, "max_wait_s must be non-negative");
        // An infinite wait means partial batches NEVER flush under the
        // max-wait policy: the end-of-trace drain would spin forever. Only
        // the round-flush variant (which flushes at the step horizon
        // instead) may disable wait deadlines.
        anyhow::ensure!(
            self.flush_at_horizon || self.cfg.max_wait_s.is_finite(),
            "max_wait_s must be finite under the max-wait flush policy"
        );
        if !self.bound {
            self.bound = true;
            self.start_s = engine.max_time(members).seconds();
            // TDG fleets (dedicated simulator/agent GMIs) pay the
            // reduced-share forward of the rejected design.
            self.dedicated = members.iter().any(|&ex| {
                engine
                    .manager()
                    .gmi(engine.gmi_of(ex))
                    .is_some_and(|g| matches!(g.role, Role::Simulator | Role::Agent))
            });
            if let Some(a) = self.cfg.autoscale {
                let scaler = Autoscaler::new(a, engine, members)?;
                self.next_window = scaler.window_s();
                self.window_lat = Some(Vec::new());
                self.scaler = Some(scaler);
            }
        }
        // A changed fleet invalidates the pooled dispatch plans: a
        // shrunken fleet's buffers may hold hops over a departed (possibly
        // failed) GPU's host path, and the single-hop reuse fast path
        // would replay them. Unchanged-membership rebinds (the steady
        // state) keep the buffers — and their capacity — untouched.
        if self.active.as_slice() != members {
            self.plans.clear();
        }
        // Rebinding (the scheduler re-places tenants every round) reuses
        // the membership buffer's capacity instead of reallocating.
        self.active.clear();
        self.active.extend_from_slice(members);
        for &ex in members {
            if !self.all_members.contains(&ex) {
                self.all_members.push(ex);
            }
        }
        Ok(())
    }

    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepOutcome> {
        anyhow::ensure!(self.bound, "gateway program stepped before bind");
        self.step_lat.clear();
        let h = ctx.horizon_s;
        loop {
            let arrivals_left = self.next_idx < self.trace.len();
            let t_arr = if arrivals_left {
                self.trace[self.next_idx].arrival_s
            } else {
                f64::INFINITY
            };
            let deadline = match self.pending.front() {
                Some(&i) => self.trace[i].arrival_s + self.cfg.max_wait_s,
                None => f64::INFINITY,
            };
            // Windows only tick while arrivals remain (the standalone
            // drain after the last arrival never re-evaluates the scaler).
            let window = if arrivals_left && self.scaler.is_some() {
                self.next_window
            } else {
                f64::INFINITY
            };
            if deadline <= t_arr && deadline <= window {
                if deadline >= h {
                    break;
                }
                self.dispatch(ctx, deadline);
            } else if window <= t_arr {
                if window >= h {
                    break;
                }
                let w = window;
                if let Some(s) = self.scaler.as_mut() {
                    let lat = self.window_lat.as_deref().unwrap_or(&[]);
                    if let Some(ev) = s.evaluate(w, ctx.engine, &mut self.active, lat) {
                        self.scale_events.push(ev);
                    }
                }
                if let Some(wl) = self.window_lat.as_mut() {
                    wl.clear();
                }
                self.next_window =
                    w + self.scaler.as_ref().map(|s| s.window_s()).unwrap_or(f64::INFINITY);
                for &ex in &self.active {
                    if !self.all_members.contains(&ex) {
                        self.all_members.push(ex);
                    }
                }
            } else if arrivals_left {
                if t_arr >= h {
                    break;
                }
                self.arrive(ctx, self.next_idx);
                self.next_idx += 1;
            } else {
                break;
            }
        }
        if self.flush_at_horizon && h.is_finite() {
            while !self.pending.is_empty() {
                self.dispatch(ctx, h);
            }
        }
        self.last_p99 = if self.step_lat.is_empty() {
            None
        } else {
            // Selected in place (the scratch is cleared at the next step
            // anyway): no per-round clone + sort. `percentile_select` is
            // bit-identical to nearest-rank over a sorted copy.
            Some(percentile_select(&mut self.step_lat, 0.99))
        };
        if self.next_idx >= self.trace.len() && self.pending.is_empty() {
            return Ok(StepOutcome::Done);
        }
        Ok(StepOutcome::Pending)
    }

    fn slo_signal(&self) -> Option<f64> {
        self.last_p99
    }

    fn snapshot(&self) -> Option<Box<dyn Workload>> {
        // Trace position, served/latency logs, and admission state
        // survive; the fleet, pooled dispatch plans, and autoscaler state
        // do not — the restore placement rebinds a fresh fleet.
        // `bound`/`start_s` carry over so the resumed program keeps its
        // original span accounting. Queued and in-flight requests ride
        // along (their indices and completion clocks are
        // placement-independent global virtual times).
        Some(Box::new(GatewayProgram {
            cfg: self.cfg,
            trace: Arc::clone(&self.trace),
            flush_at_horizon: self.flush_at_horizon,
            active: Vec::new(),
            all_members: self.all_members.clone(),
            dedicated: self.dedicated,
            bound: self.bound,
            start_s: self.start_s,
            next_idx: self.next_idx,
            pending: self.pending.clone(),
            served: self.served.clone(),
            batch_sizes: self.batch_sizes.clone(),
            rejected: self.rejected,
            outstanding: self.outstanding,
            max_queue_depth: self.max_queue_depth,
            completions: self.completions.clone(),
            scaler: None,
            scale_events: self.scale_events.clone(),
            next_window: f64::INFINITY,
            window_lat: None,
            step_lat: Vec::new(),
            last_p99: None,
            plans: DispatchPlans::default(),
        }))
    }

    fn finish(&mut self, engine: &Engine, fabric: &Fabric) -> RunMetrics {
        let mut lats: Vec<f64> = self.served.iter().map(|s| s.latency_s()).collect();
        let total = self.trace.len();
        let served_n = self.served.len();
        let within = self
            .served
            .iter()
            .filter(|s| s.latency_s() <= self.cfg.slo_s + 1e-12)
            .count();
        // Mean over dispatch order, BEFORE the selections below permute
        // the buffer (the sum is order-sensitive in the last bits but the
        // dispatch order is itself deterministic).
        let mean_s = if served_n > 0 {
            lats.iter().sum::<f64>() / served_n as f64
        } else {
            0.0
        };
        let mean_batch = if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        };
        let latency = LatencyStats {
            requests: total,
            served: served_n,
            rejected: self.rejected,
            p50_s: percentile_select(&mut lats, 0.50),
            p95_s: percentile_select(&mut lats, 0.95),
            p99_s: percentile_select(&mut lats, 0.99),
            mean_s,
            slo_s: self.cfg.slo_s,
            attainment: if total > 0 { within as f64 / total as f64 } else { 1.0 },
            mean_batch,
            max_queue_depth: self.max_queue_depth,
        };
        let span = engine.max_time(&self.all_members).seconds() - self.start_s;
        let peak_mem = self
            .active
            .iter()
            .filter_map(|&ex| engine.manager().gmi(engine.gmi_of(ex)))
            .map(|g| g.mem_gib)
            .fold(0.0f64, f64::max);
        RunMetrics {
            steps_per_sec: if span > 0.0 { served_n as f64 / span } else { 0.0 },
            pps: if span > 0.0 { served_n as f64 / span } else { 0.0 },
            ttop: 0.0,
            span_s: span,
            utilization: engine.mean_utilization(),
            final_reward: 0.0,
            reward_curve: vec![],
            comm_s: super::scoped_comm_s(engine, &self.all_members),
            peak_mem_gib: peak_mem,
            links: fabric.link_report(),
            latency: Some(latency),
            replay: None,
        }
    }
}
