//! # GMI-DRL
//!
//! Reproduction of *"GMI-DRL: Empowering Multi-GPU Deep Reinforcement
//! Learning with GPU Spatial Multiplexing"* (Wang et al., 2022) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate is the **Layer-3 coordinator**: it owns the GMI abstraction
//! (resource-adjustable sub-GPU instances backed by simulated MPS / MIG
//! partitions), the specialized inter-GMI communication layer (layout-aware
//! gradient reduction, channel-based experience sharing), the adaptive GMI
//! management strategy (task-aware mapping + workload-aware selection), and
//! the DRL orchestrators (serving, sync PPO, async A3C) plus the Isaac-Gym
//! style baselines the paper evaluates against.
//!
//! Real numerics (policy forward/backward, environment physics, Adam) run
//! through AOT-lowered HLO artifacts executed on the PJRT CPU client
//! ([`runtime`]); GPU *timing* is accounted by the calibrated virtual
//! timeline ([`vtime`]) per DESIGN.md §5.

pub mod baselines;
pub mod channels;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod drl;
pub mod gmi;
pub mod mapping;
pub mod metrics;
pub mod runtime;
pub mod selection;
pub mod vtime;

pub use config::{BenchInfo, Manifest};
pub use runtime::{ArtifactKind, ExecHandle, HostTensor};
