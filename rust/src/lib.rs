//! # GMI-DRL
//!
//! Reproduction of *"GMI-DRL: Empowering Multi-GPU Deep Reinforcement
//! Learning with GPU Spatial Multiplexing"* (Wang et al., 2022) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate is the **Layer-3 coordinator**: it owns the GMI abstraction
//! (resource-adjustable sub-GPU instances backed by simulated MPS / MIG
//! partitions), the specialized inter-GMI communication layer (layout-aware
//! gradient reduction, channel-based experience sharing), the adaptive GMI
//! management strategy (task-aware mapping + workload-aware selection), and
//! the DRL orchestrators (serving, sync PPO, async A3C) plus the Isaac-Gym
//! style baselines the paper evaluates against.
//!
//! Real numerics (policy forward/backward, environment physics, Adam) run
//! through AOT-lowered HLO artifacts executed on the PJRT CPU client
//! ([`runtime`]); GPU *timing* is accounted by the calibrated virtual
//! timeline ([`vtime`]) per DESIGN.md §5.
//!
//! ## Architecture: how a run is put together
//!
//! ```text
//! scheduler       sched::{run_cluster, JobSpec}          multi-tenant co-scheduling:
//!       │                                                admission, priority preemption,
//!       │  bind / step / slo_signal / finish             SLO pressure, restore, fairness
//!       ▼
//! workloads       workload::{SyncProgram, AsyncProgram,  steppable workload programs —
//!                 ClosedServingProgram, GatewayProgram,   ONE implementation per workload
//!                 ReplayProgram, LeagueProgram}
//!       ▲  build + step to completion
//!       │
//! drivers         drl::{serving, sync, a3c}, baselines,  thin standalone entrypoints
//!                 serve::{gateway, autoscale}
//!       │  charge(ops) / collectives / transfers
//!       ▼
//! engine          engine::{Engine, elastic}              discrete-event executor:
//!       │                                                clocks, shares, busy/idle,
//!       │  execute(plan)                                 utilization, elastic resize
//!       ▼
//! fabric          fabric::{Fabric, Plan, Route}          links + routes + collective
//!       │                                                planner (MPR/MRR/HAR and the
//!       │  link costs                                    multi-node hierarchy as plans),
//!       ▼                                                per-link occupancy and stats
//! substrate       gmi (manager/backends), mapping,       placement + validation,
//!                 comm (LGR arithmetic), channels,       reduction numerics, experience
//!                 cluster (topology), vtime (cost)       pipeline, calibrated link model
//! ```
//!
//! Orchestrators never touch `Clock`, `UtilizationTracker`, share math, or
//! link costs: they describe work as [`engine::OpCharge`] sequences and
//! communication as [`fabric`] transfer plans executed through engine
//! primitives (`collective`, `collective_overlapped`, `recv_plan`,
//! `broadcast_plan`, plus the scalar `barrier_advance` / `recv` /
//! `broadcast`), and read span/utilization/communication and per-link
//! traffic totals back out. Overlapped collectives drain on the fabric's
//! links while executors keep computing — the sync trainer starts the next
//! rollout while the last gradient allreduce drains, re-synchronizing where
//! the reduced parameters are actually consumed. The engine also owns a
//! live clone of the [`gmi::GmiManager`], which lets the
//! [`engine::elastic`] controller re-provision SM shares between iterations
//! (validated `resize_gmi`) without mutating the caller's static
//! [`mapping::Layout`].
//!
//! The [`serve`] layer turns the same substrate into an SLO-aware serving
//! system: an open-loop traffic generator ([`serve::traffic`]) drives a
//! gateway with admission control and dynamic batching
//! ([`serve::run_gateway`]), and an autoscaler ([`serve::autoscale`]) uses
//! the whole-GMI elastic paths ([`engine::Engine::add_gmi`] /
//! [`engine::Engine::remove_gmi`]) to track the latency target — per-request
//! percentiles land in [`metrics::LatencyStats`] on the run's
//! [`metrics::RunMetrics`].
//!
//! The [`workload`] layer is what keeps the standalone drivers and the
//! scheduler from diverging: every workload (sync PPO, A3C, closed-loop
//! serving, the open-loop gateway, the off-policy replay learner, the
//! self-play league) is ONE steppable
//! [`workload::Workload`] program — a round-based coroutine over the
//! shared engine + fabric with `bind` (membership hooks for
//! preempt/resize/restore), `step` (charge up to a horizon), and `finish`
//! (fold to [`metrics::RunMetrics`]). Standalone drivers step a program
//! with an infinite horizon; the scheduler steps the same program one
//! scheduling round at a time, so a single-tenant cluster run is
//! bit-identical to the standalone run (`rust/tests/prop_workload.rs`).
//!
//! Two off-policy kinds stress what on-policy tenants never touch. The
//! replay learner ([`workload::replay`], [`sched::JobSpec::replay`])
//! streams collector transitions through the compressor-channel pipeline
//! into a memory-budgeted buffer (FIFO or seeded-reservoir eviction)
//! that a decoupled learner samples at its own rate — buffer pressure and
//! sample staleness land in [`metrics::ReplayStats`], and delivery is
//! conserved exactly across preemption and fault kills. The self-play
//! league ([`workload::league`], [`sched::JobSpec::league`]) is a
//! coordinator that creates tenants at runtime: matches paired by a
//! closed-form circle schedule are spawned as child jobs through
//! [`workload::Workload::take_spawn_requests`], admitted through the
//! scheduler's normal path, and folded back into an Elo win-rate table
//! via [`workload::Workload::child_result`] (dedup-by-tag, so a faulted
//! season replays bit-identically). `rust/tests/prop_offpolicy.rs` locks
//! the churn invariants.
//!
//! The [`sched`] layer drops the one-job-per-cluster assumption: a queue
//! of heterogeneous tenants ([`sched::JobSpec`] — training runs, A3C
//! pipelines, closed-loop collectors, serving fleets with SLO classes)
//! co-executes on ONE shared engine, each tenant a [`workload::Workload`]
//! program built by its [`sched::JobKind`] constructor. Executors carry
//! job tags, so per-job busy/communication totals and cross-job
//! interference seconds fall out of the same accounting, and the
//! scheduler preempts (validated shrink + evict, floor-guarded by the
//! manager's typed [`gmi::RemoveGmiError`]) and restores tenants as
//! priorities and SLO pressure dictate — see `examples/shared_cluster.rs`
//! for the preemption timeline against a statically partitioned baseline.
//!
//! ## Performance
//!
//! The inner loops are sized for million-request cluster days: the engine
//! maintains its global/per-GPU clock frontiers and per-job service
//! totals incrementally at charge time (O(1) queries;
//! `#[doc(hidden)] *_scan()` keeps the fold-over-all-executors reference
//! implementations, cross-checked by
//! [`engine::Engine::audit_incremental_state`]), the gateway dispatch
//! path reuses pooled fabric plans ([`serve::DispatchPlans`]) and shared
//! `Arc<[Request]>` traces, latency percentiles select in place
//! ([`metrics::percentile_select`]), and the cluster scheduler's round
//! loop runs allocation-free in steady state (reused priority-order
//! scratch; `needs_restore` / placement-dirty flags skip untouched
//! tenants and unchanged peak scans). Every rewrite preserves arithmetic
//! and event order bit-for-bit — `rust/tests/determinism.rs` pins a
//! committed scenario fingerprint (`rust/tests/golden/`) and
//! `rust/tests/serve_gateway.rs` pins the no-realloc property. Wall-clock
//! is tracked by `benches/hotpath.rs` and `benches/bench_cluster_day.rs`,
//! which emit `BENCH_*.json` and gate CI against committed baselines
//! (EXPERIMENTS.md §Perf).
//!
//! ## Auto-tuning
//!
//! [`tune`] generalizes Algorithm 2 into an online auto-tuner: instead of
//! trusting the calibrated cost model alone, it runs short **measured
//! probe runs** through the same [`workload::Workload`] programs the long
//! run will use (scratch Engine+Fabric, reduced rollout / trace prefix /
//! round count), searching the joint space — GMIs per GPU (which fixes
//! the quantized SM share) x num_env x minibatches x reduce strategy
//! (auto/mpr/mrr/har) x overlap for sync training
//! ([`tune::tune_sync`]), `max_batch x max_wait` against the SLO for the
//! gateway ([`tune::tune_gateway`]), `num_env x batch_samples x
//! param_sync_every` for A3C ([`tune::tune_async`]), and the minibatch
//! count at scheduler admission, charged to the tenant in virtual time
//! ([`tune::tune_admission_minibatches`], [`sched::JobSpec`]
//! `with_admission_tuning`). The Algorithm-2 saturation rule prunes the
//! grid before any probe spends time, successive halving focuses the
//! budget (default <1% of the projected run horizon,
//! [`config::DEFAULT_TUNE_BUDGET_FRAC`]) on contenders, and a
//! full-fidelity final lock probes the composed winner against the
//! hand-picked default and the `explore()` pick — so the tuned
//! configuration beats or matches both by measurement. Every decision is
//! bit-reproducible (`rust/tests/prop_tune.rs`); `--autotune` wires it
//! into the `train-sync`, `train-async`, and `serve` CLI paths.
//!
//! ## Fault tolerance
//!
//! [`fault`] makes the shared cluster survivable: a seeded, deterministic
//! failure-trace generator ([`fault::FaultTrace::generate`] — splitmix64
//! streams with exponential inter-arrival per failure class) or a
//! declarative trace file ([`fault::FaultTrace::parse`]) schedules GPU,
//! whole-node, NVSwitch, and InfiniBand failures (and repairs), which the
//! scheduler applies to the shared [`fabric::Fabric`] between rounds
//! ([`sched::SchedConfig`] `faults`). Dead GPUs and links invalidate
//! routes: the collective planner falls to the next-cheapest valid plan
//! ([`fabric::Fabric::try_cheapest_allreduce`]) or reports a partition,
//! running tenants are re-planned over the degraded fabric, tenants with
//! members on dead hardware are killed and re-queued, and a failed GPU is
//! never a placement target. With a finite `checkpoint_interval_s`, every
//! running tenant is periodically captured through
//! [`workload::Workload::snapshot`] — the capture cost charged to the
//! tenant's own executor clocks in virtual time — so a killed tenant is
//! re-admitted onto surviving capacity resumed from its last checkpoint,
//! bounding goodput loss to one interval per kill. Per-job kills, lost
//! GPU-seconds, recovery latency, and checkpoint overhead land in
//! [`sched::JobReport`]; the whole faulted day is bit-reproducible
//! (`rust/tests/prop_fault.rs`, the pinned golden in
//! `rust/tests/determinism.rs`, and `examples/failure_day.rs`).

pub mod baselines;
pub mod channels;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod drl;
pub mod engine;
pub mod fabric;
pub mod fault;
pub mod gmi;
pub mod mapping;
pub mod metrics;
pub mod runtime;
pub mod sched;
pub mod selection;
pub mod serve;
pub mod tune;
pub mod vtime;
pub mod workload;

pub use config::{BenchInfo, Manifest};
pub use runtime::{ArtifactKind, ExecHandle, HostTensor};
