//! # GMI-DRL
//!
//! Reproduction of *"GMI-DRL: Empowering Multi-GPU Deep Reinforcement
//! Learning with GPU Spatial Multiplexing"* (Wang et al., 2022) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate is the **Layer-3 coordinator**: it owns the GMI abstraction
//! (resource-adjustable sub-GPU instances backed by simulated MPS / MIG
//! partitions), the specialized inter-GMI communication layer (layout-aware
//! gradient reduction, channel-based experience sharing), the adaptive GMI
//! management strategy (task-aware mapping + workload-aware selection), and
//! the DRL orchestrators (serving, sync PPO, async A3C) plus the Isaac-Gym
//! style baselines the paper evaluates against.
//!
//! Real numerics (policy forward/backward, environment physics, Adam) run
//! through AOT-lowered HLO artifacts executed on the PJRT CPU client
//! ([`runtime`]); GPU *timing* is accounted by the calibrated virtual
//! timeline ([`vtime`]) per DESIGN.md §5.
//!
//! ## Architecture: how a run is put together
//!
//! ```text
//! orchestrators   drl::{serving, sync, a3c}, baselines   what runs when
//!       │  charge(ops) / barriers / transfers
//!       ▼
//! engine          engine::{Engine, elastic}              discrete-event executor:
//!       │                                                clocks, shares, busy/idle,
//!       │                                                utilization, elastic resize
//!       ▼
//! substrate       gmi (manager/backends), mapping,       placement + validation,
//!                 comm (LGR), channels, cluster, vtime   costs and transports
//! ```
//!
//! Orchestrators never touch `Clock`, `UtilizationTracker`, or share math:
//! they describe work as [`engine::OpCharge`] sequences and synchronization
//! as engine primitives (`barrier_advance`, `recv`, `broadcast`), and read
//! span/utilization/communication totals back from the [`engine::Engine`].
//! The engine in turn owns a live clone of the [`gmi::GmiManager`], which
//! lets the [`engine::elastic`] controller re-provision SM shares between
//! iterations (validated `resize_gmi`) without mutating the caller's
//! static [`mapping::Layout`].

pub mod baselines;
pub mod channels;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod drl;
pub mod engine;
pub mod gmi;
pub mod mapping;
pub mod metrics;
pub mod runtime;
pub mod selection;
pub mod vtime;

pub use config::{BenchInfo, Manifest};
pub use runtime::{ArtifactKind, ExecHandle, HostTensor};
