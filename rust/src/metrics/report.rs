//! Plain-text table rendering for the bench harnesses (the offline build
//! has no criterion; benches are plain mains that print the paper's tables
//! — see DESIGN.md §Dependencies).

/// A simple aligned-column table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Render per-link fabric traffic (bytes moved, busy seconds, achieved
/// bandwidth) as a table — the comm half of the run report.
pub fn link_table(links: &[super::LinkReport]) -> Table {
    let mut t = Table::new(&["Link", "MiB moved", "busy s", "MiB/s"]);
    for l in links {
        let mib = l.bytes as f64 / (1024.0 * 1024.0);
        let rate = if l.busy_s > 0.0 { mib / l.busy_s } else { 0.0 };
        t.row(vec![
            l.name.clone(),
            format!("{mib:.2}"),
            format!("{:.4}", l.busy_s),
            fmt_rate(rate),
        ]);
    }
    t
}

/// Render the request-latency distribution of an open-loop serving run
/// (percentiles, SLO attainment, batching and queueing outcomes) — the
/// latency half of the gateway report.
pub fn latency_table(l: &super::LatencyStats) -> Table {
    let mut t = Table::new(&["Latency", "value"]);
    t.row(vec!["requests".into(), fmt_rate(l.requests as f64)]);
    t.row(vec!["served".into(), fmt_rate(l.served as f64)]);
    t.row(vec!["rejected".into(), fmt_rate(l.rejected as f64)]);
    t.row(vec!["p50 (ms)".into(), format!("{:.3}", l.p50_s * 1e3)]);
    t.row(vec!["p95 (ms)".into(), format!("{:.3}", l.p95_s * 1e3)]);
    t.row(vec!["p99 (ms)".into(), format!("{:.3}", l.p99_s * 1e3)]);
    t.row(vec!["mean (ms)".into(), format!("{:.3}", l.mean_s * 1e3)]);
    t.row(vec!["SLO (ms)".into(), format!("{:.3}", l.slo_s * 1e3)]);
    t.row(vec![
        "SLO attainment".into(),
        format!("{:.2}%", 100.0 * l.attainment),
    ]);
    t.row(vec!["mean batch".into(), format!("{:.1}", l.mean_batch)]);
    t.row(vec![
        "peak queue depth".into(),
        fmt_rate(l.max_queue_depth as f64),
    ]);
    t
}

/// Format a rate like the paper's tables (e.g. 207834 -> "207,834").
pub fn fmt_rate(v: f64) -> String {
    let n = v.round() as i64;
    let s = n.abs().to_string();
    let mut out = String::new();
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    if n < 0 {
        format!("-{out}")
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Bench", "Baseline", "LGR"]);
        t.row(vec!["AT".into(), "107,689".into(), "114,734".into()]);
        t.row(vec!["HM".into(), "163,723".into(), "168,300".into()]);
        let s = t.render();
        assert!(s.contains("| AT    |"));
        assert!(s.lines().count() == 4);
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn link_table_renders_rates() {
        let links = vec![
            crate::metrics::LinkReport {
                name: "host:gpu0".into(),
                bytes: 2 * 1024 * 1024,
                busy_s: 0.5,
            },
            crate::metrics::LinkReport { name: "nvswitch".into(), bytes: 0, busy_s: 0.0 },
        ];
        let s = link_table(&links).render();
        assert!(s.contains("host:gpu0"));
        assert!(s.contains("2.00"));
        // zero-busy links report a zero rate instead of dividing by zero
        assert!(s.contains("nvswitch"));
    }

    #[test]
    fn latency_table_renders() {
        let l = crate::metrics::LatencyStats {
            requests: 1000,
            served: 990,
            rejected: 10,
            p50_s: 1.5e-3,
            p95_s: 4.0e-3,
            p99_s: 9.25e-3,
            mean_s: 2.0e-3,
            slo_s: 10e-3,
            attainment: 0.97,
            mean_batch: 12.5,
            max_queue_depth: 64,
        };
        let s = latency_table(&l).render();
        assert!(s.contains("9.250"), "{s}");
        assert!(s.contains("97.00%"), "{s}");
        assert!(s.contains("12.5"), "{s}");
        assert!(s.contains("64"), "{s}");
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(207834.4), "207,834");
        assert_eq!(fmt_rate(999.0), "999");
        assert_eq!(fmt_rate(1000.0), "1,000");
        assert_eq!(fmt_rate(1535785.0), "1,535,785");
        assert_eq!(fmt_rate(0.0), "0");
    }
}
