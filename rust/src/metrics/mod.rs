//! Run metrics: throughput (steps/s, PPS, TTOP), per-GPU utilization
//! (Fig 1b's quantity), reward accumulation (Fig 9), and per-link fabric
//! traffic totals.

pub mod report;

pub use report::{fmt_rate, latency_table, link_table, Table};

/// Traffic totals of one fabric link over a run (produced by
/// [`fabric::Fabric::link_report`](crate::fabric::Fabric::link_report)).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LinkReport {
    /// Link name, e.g. `host:gpu0`, `nvswitch`, `cpu-reduce`, `ib`.
    pub name: String,
    /// Payload bytes that crossed the link.
    pub bytes: u64,
    /// Virtual seconds the link spent busy.
    pub busy_s: f64,
}

use std::collections::BTreeMap;

/// Request-latency distribution of an open-loop serving run (produced by
/// [`serve::run_gateway`](crate::serve::run_gateway)). All times are
/// virtual seconds on the engine timeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencyStats {
    /// Arrivals in the trace (admitted + rejected).
    pub requests: usize,
    pub served: usize,
    /// Arrivals turned away by admission control.
    pub rejected: usize,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_s: f64,
    /// The per-request latency SLO the run was measured against.
    pub slo_s: f64,
    /// Fraction of ALL requests served within the SLO (a rejection is an
    /// SLO miss).
    pub attainment: f64,
    /// Mean dispatched batch size (the dynamic-batching outcome).
    pub mean_batch: f64,
    /// Peak outstanding requests (queued + in-flight) seen at any arrival.
    pub max_queue_depth: usize,
}

/// Jain's fairness index over non-negative per-tenant service totals:
/// 1.0 = perfectly even service, 1/n = one tenant got everything. The
/// cluster scheduler reports it over per-job busy GPU-seconds. Empty (or
/// all-zero) input reports 1.0 — nothing was served unfairly.
pub fn jain_index(service: &[f64]) -> f64 {
    let n = service.len();
    if n == 0 {
        return 1.0;
    }
    let s: f64 = service.iter().sum();
    let s2: f64 = service.iter().map(|x| x * x).sum();
    if s2 <= 0.0 {
        return 1.0;
    }
    (s * s) / (n as f64 * s2)
}

/// Nearest-rank percentile of an ascending-sorted slice, `q` in [0, 1].
/// Empty input reports 0.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 0 {
        return 0.0;
    }
    let rank = (q * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Nearest-rank percentile of an UNSORTED sample via in-place selection
/// (`select_nth_unstable` under `f64::total_cmp`) — O(n) instead of the
/// O(n log n) full sort, and bit-identical to [`percentile`] on the sorted
/// copy: the nearest-rank statistic is a single order statistic, and
/// `total_cmp` is a total order, so the k-th element is the same value
/// either way. The slice is reordered (partitioned around the rank), not
/// sorted. Empty input reports 0.
pub fn percentile_select(samples: &mut [f64], q: f64) -> f64 {
    let n = samples.len();
    if n == 0 {
        return 0.0;
    }
    let rank = (q * n as f64).ceil() as usize;
    let k = rank.clamp(1, n) - 1;
    let (_, kth, _) = samples.select_nth_unstable_by(k, f64::total_cmp);
    *kth
}

/// Bounded sample pool for percentile estimation: **exact below the cap,
/// a seeded Algorithm-R reservoir above it**. The week-scale serving path
/// pushes one latency per request; holding 10^7 f64s per window is the
/// memory cost this bounds. Two guarantees make it safe to substitute for
/// a plain `Vec<f64>`:
///
/// * while `seen() <= cap` every sample is retained in push order, so any
///   statistic over [`samples`](Self::samples) is bit-identical to the
///   unbounded path (the sub-cap identity the property suite locks in);
/// * [`sum`](Self::sum) (and therefore the mean) accumulates every pushed
///   sample in push order regardless of the cap, so means stay exact even
///   when percentiles come from the reservoir.
///
/// Replacement draws come from a dedicated SplitMix64 stream seeded at
/// construction, so capped runs replay bit-identically too.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleReservoir {
    cap: usize,
    rng_state: u64,
    seen: u64,
    sum: f64,
    samples: Vec<f64>,
}

impl SampleReservoir {
    /// Unbounded: behaves exactly like a `Vec<f64>` push log.
    pub fn unbounded() -> Self {
        SampleReservoir::capped(usize::MAX, 0)
    }

    /// Retain at most `cap` samples (`cap >= 1`), replacing uniformly at
    /// random from the seeded stream once full.
    pub fn capped(cap: usize, seed: u64) -> Self {
        SampleReservoir {
            cap: cap.max(1),
            rng_state: seed,
            seen: 0,
            sum: 0.0,
            samples: Vec::new(),
        }
    }

    fn next_u64(&mut self) -> u64 {
        self.rng_state = self.rng_state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.rng_state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn push(&mut self, v: f64) {
        self.sum += v;
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            // Algorithm R: replace slot j ~ U[0, seen) if it lands in the
            // reservoir. `seen` already counts v, so the draw is over the
            // full stream so far.
            let j = (self.next_u64() % self.seen) as usize;
            if j < self.cap {
                self.samples[j] = v;
            }
        }
    }

    /// Total samples pushed (not the retained count).
    pub fn seen(&self) -> usize {
        self.seen as usize
    }

    /// Exact running sum over every pushed sample, in push order.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Whether every pushed sample is still retained (sub-cap regime).
    pub fn is_exact(&self) -> bool {
        self.seen as usize <= self.cap
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Heap capacity of the retained-sample buffer (pool-stability
    /// checks; NOT the configured cap).
    pub fn capacity(&self) -> usize {
        self.samples.capacity()
    }

    /// Mutable view for in-place [`percentile_select`].
    pub fn samples_mut(&mut self) -> &mut [f64] {
        &mut self.samples
    }

    /// Reset the sample log and accumulators for the next window. The
    /// replacement RNG stream intentionally carries across windows — one
    /// seed per program replays the whole run deterministically.
    pub fn clear(&mut self) {
        self.samples.clear();
        self.seen = 0;
        self.sum = 0.0;
    }
}

/// Per-GPU SM-time accounting: utilization = busy SM-seconds / (span * SMs).
#[derive(Debug, Default, Clone)]
pub struct UtilizationTracker {
    /// gpu -> (busy sm-seconds, latest clock seen)
    per_gpu: BTreeMap<usize, (f64, f64)>,
}

impl UtilizationTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an op: it occupied `occupancy` (fraction of the GPU's SMs)
    /// for `dur` virtual seconds, finishing at `end` on `gpu`.
    pub fn record(&mut self, gpu: usize, occupancy: f64, dur: f64, end: f64) {
        let e = self.per_gpu.entry(gpu).or_insert((0.0, 0.0));
        e.0 += occupancy * dur;
        if end > e.1 {
            e.1 = end;
        }
    }

    /// Utilization of one GPU in [0, 1].
    pub fn gpu_utilization(&self, gpu: usize) -> f64 {
        match self.per_gpu.get(&gpu) {
            Some((busy, span)) if *span > 0.0 => (busy / span).min(1.0),
            _ => 0.0,
        }
    }

    /// Mean utilization across all GPUs that saw work.
    pub fn mean_utilization(&self) -> f64 {
        if self.per_gpu.is_empty() {
            return 0.0;
        }
        let s: f64 = self.per_gpu.keys().map(|&g| self.gpu_utilization(g)).sum();
        s / self.per_gpu.len() as f64
    }
}

/// Throughput summary for one run (all rates in events per *virtual* second).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// aggregate simulation env-steps per second (the paper's steps/s).
    pub steps_per_sec: f64,
    /// agent predictions per second (Fig 11 PPS).
    pub pps: f64,
    /// training samples consumed per second (Fig 11 TTOP).
    pub ttop: f64,
    /// total virtual span of the run.
    pub span_s: f64,
    /// mean GPU utilization in [0,1].
    pub utilization: f64,
    /// mean reward of the final iteration (learning signal).
    pub final_reward: f64,
    /// (virtual seconds, mean reward) samples over the run (Fig 9).
    pub reward_curve: Vec<(f64, f64)>,
    /// communication seconds spent in gradient reduction.
    pub comm_s: f64,
    /// peak device memory of any GMI (GiB).
    pub peak_mem_gib: f64,
    /// per-link fabric traffic (bytes / busy seconds), when the run went
    /// through the communication fabric.
    pub links: Vec<LinkReport>,
    /// request-latency distribution, for open-loop serving runs
    /// (closed-loop runs have no request arrivals to measure).
    pub latency: Option<LatencyStats>,
    /// replay-buffer occupancy and sample-staleness statistics, for
    /// off-policy runs (on-policy and serving runs have no buffer).
    pub replay: Option<ReplayStats>,
}

impl RunMetrics {
    /// Lengthen the virtual span by `extra_s` seconds of communication
    /// overhead, rescaling every throughput rate accordingly. Used by
    /// baselines whose backend adds launch/coordination latency on top of
    /// an engine-computed run (per-tensor NCCL launches, the Horovod
    /// coordinator cycle).
    pub fn stretch_span(&mut self, extra_s: f64) {
        if extra_s <= 0.0 || self.span_s <= 0.0 {
            return;
        }
        let new_span = self.span_s + extra_s;
        let scale = self.span_s / new_span;
        self.steps_per_sec *= scale;
        self.pps *= scale;
        self.ttop *= scale;
        self.comm_s += extra_s;
        self.span_s = new_span;
    }

    pub fn print_summary(&self, label: &str) {
        println!(
            "{label}: {:.0} steps/s | pps {:.0} | ttop {:.0} | util {:.1}% | comm {:.3}s | span {:.2}s | reward {:.3}",
            self.steps_per_sec,
            self.pps,
            self.ttop,
            100.0 * self.utilization,
            self.comm_s,
            self.span_s,
            self.final_reward,
        );
    }

    /// Print the per-link fabric traffic table (no-op when the run did not
    /// go through the fabric).
    pub fn print_links(&self) {
        if self.links.is_empty() {
            return;
        }
        link_table(&self.links).print();
    }

    /// Print the request-latency table (no-op for closed-loop runs).
    pub fn print_latency(&self) {
        if let Some(l) = &self.latency {
            latency_table(l).print();
        }
    }

    /// Print the replay-buffer summary line (no-op for on-policy runs).
    pub fn print_replay(&self) {
        if let Some(r) = &self.replay {
            println!(
                "replay: {} in / {} sampled / {} evicted (cap {}) | staleness mean {:.4}s max {:.4}s | pressure mean {:.2} peak {:.2} | {} empty tick(s)",
                r.transitions_in,
                r.transitions_sampled,
                r.evicted,
                r.capacity,
                r.mean_staleness_s,
                r.max_staleness_s,
                r.mean_pressure,
                r.peak_pressure,
                r.empty_ticks,
            );
        }
    }
}

/// Replay-buffer statistics an off-policy run reports in
/// [`RunMetrics::replay`]. Every mean is guarded against empty windows
/// (a learner that ticks before any collector flush reports zeros, never
/// NaN) — the same audit discipline as [`LatencyStats`] on empty windows.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplayStats {
    /// Buffer capacity in transitions (derived from the memory budget).
    pub capacity: usize,
    /// Transitions delivered into the buffer over the run. Conserved
    /// across preemption and fault kills: lost in-flight transitions are
    /// re-done, so this matches the collection schedule exactly.
    pub transitions_in: usize,
    /// Transitions the learner sampled (with replacement) over the run.
    pub transitions_sampled: usize,
    /// Transitions evicted by the (FIFO or reservoir) policy.
    pub evicted: usize,
    /// Learner gradient updates applied.
    pub updates: usize,
    /// Learner ticks that found the buffer empty (sampled nothing).
    pub empty_ticks: usize,
    /// Mean age (virtual seconds since collection) of sampled
    /// transitions; 0 when nothing was sampled.
    pub mean_staleness_s: f64,
    /// Worst sampled-transition age (virtual seconds).
    pub max_staleness_s: f64,
    /// Mean buffer occupancy / capacity at learner ticks; 0 without ticks.
    pub mean_pressure: f64,
    /// Peak buffer occupancy / capacity ever observed.
    pub peak_pressure: f64,
}

/// Accumulates reward samples during a run.
#[derive(Debug, Default, Clone)]
pub struct RewardTracker {
    pub curve: Vec<(f64, f64)>,
    pub cumulative: f64,
}

impl RewardTracker {
    pub fn push(&mut self, vtime: f64, mean_reward: f64) {
        self.cumulative += mean_reward;
        self.curve.push((vtime, self.cumulative));
    }

    pub fn final_reward(&self) -> f64 {
        self.curve.last().map(|&(_, r)| r).unwrap_or(0.0)
    }

    /// Cumulative reward reached by `t` (linear scan; curves are short).
    pub fn reward_at(&self, t: f64) -> f64 {
        let mut last = 0.0;
        for &(ts, r) in &self.curve {
            if ts > t {
                break;
            }
            last = r;
        }
        last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_accounting() {
        let mut u = UtilizationTracker::new();
        // one op at 30% occupancy for the whole 10s span
        u.record(0, 0.3, 10.0, 10.0);
        assert!((u.gpu_utilization(0) - 0.3).abs() < 1e-9);
        // add a concurrent op at 50% for half the span
        u.record(0, 0.5, 5.0, 10.0);
        assert!((u.gpu_utilization(0) - 0.55).abs() < 1e-9);
        assert_eq!(u.gpu_utilization(3), 0.0);
    }

    #[test]
    fn utilization_clamped() {
        let mut u = UtilizationTracker::new();
        u.record(0, 1.0, 20.0, 10.0); // oversubscribed
        assert_eq!(u.gpu_utilization(0), 1.0);
    }

    #[test]
    fn stretch_span_rescales_rates() {
        let mut m = RunMetrics {
            steps_per_sec: 100.0,
            pps: 100.0,
            ttop: 50.0,
            span_s: 10.0,
            comm_s: 1.0,
            ..Default::default()
        };
        m.stretch_span(10.0);
        assert_eq!(m.span_s, 20.0);
        assert_eq!(m.steps_per_sec, 50.0);
        assert_eq!(m.ttop, 25.0);
        assert_eq!(m.comm_s, 11.0);
        // non-positive extras are no-ops
        let before = m.steps_per_sec;
        m.stretch_span(0.0);
        assert_eq!(m.steps_per_sec, before);
    }

    #[test]
    fn mean_across_gpus() {
        let mut u = UtilizationTracker::new();
        u.record(0, 0.2, 10.0, 10.0);
        u.record(1, 0.6, 10.0, 10.0);
        assert!((u.mean_utilization() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn jain_index_ranges() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        assert!((jain_index(&[3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
        // One tenant got everything: 1/n.
        assert!((jain_index(&[5.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        // Mild skew sits strictly between.
        let j = jain_index(&[2.0, 1.0]);
        assert!(j > 0.5 && j < 1.0, "jain {j}");
        // Scale-invariant.
        assert!((jain_index(&[2.0, 1.0]) - jain_index(&[20.0, 10.0])).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        // three elements: p50 is the middle one
        assert_eq!(percentile(&[1.0, 2.0, 3.0], 0.5), 2.0);
    }

    /// Property test: on random samples (mixed magnitudes, duplicates,
    /// negative zeros), selection-based p50/p95/p99 are bit-identical to
    /// the sorted nearest-rank reference.
    #[test]
    fn percentile_select_matches_sorted_reference() {
        // SplitMix64: deterministic sample generator, no external deps.
        fn splitmix(state: &mut u64) -> u64 {
            *state = state.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = *state;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        }
        let mut state = 0xDEADBEEFu64;
        for trial in 0..200 {
            let n = (splitmix(&mut state) % 257) as usize;
            let mut sample: Vec<f64> = (0..n)
                .map(|_| {
                    let r = splitmix(&mut state);
                    // Mixed magnitudes with ~1/8 duplicates and zeros.
                    match r % 8 {
                        0 => 0.0,
                        1 => -0.0,
                        2 => 0.125,
                        _ => (r >> 11) as f64 / (1u64 << 53) as f64 * 1e3 - 250.0,
                    }
                })
                .collect();
            let mut sorted = sample.clone();
            sorted.sort_by(f64::total_cmp);
            for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
                let want = percentile(&sorted, q);
                let got = percentile_select(&mut sample, q);
                assert_eq!(
                    want.to_bits(),
                    got.to_bits(),
                    "trial {trial} n {n} q {q}: sorted {want} select {got}"
                );
            }
        }
    }

    /// Sub-cap identity: while the stream fits under the cap, the
    /// reservoir IS the plain push log — identical retained samples (in
    /// push order), identical sums, so every downstream statistic is
    /// bit-identical to the unbounded path.
    #[test]
    fn reservoir_below_cap_is_bit_identical_to_a_vec() {
        let mut state = 0x1234_5678u64;
        let mut next = move || {
            state = state.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            (z ^ (z >> 31)) as f64 / u64::MAX as f64
        };
        let stream: Vec<f64> = (0..500).map(|_| next()).collect();
        let mut res = SampleReservoir::capped(500, 99);
        let mut unb = SampleReservoir::unbounded();
        let mut vec_sum = 0.0;
        for &v in &stream {
            res.push(v);
            unb.push(v);
            vec_sum += v;
        }
        assert!(res.is_exact());
        assert_eq!(res.samples(), &stream[..]);
        assert_eq!(res.samples(), unb.samples());
        assert_eq!(res.sum().to_bits(), vec_sum.to_bits());
        assert_eq!(res.seen(), 500);

        // Over the cap: bounded retention, exact sum, deterministic replay.
        let mut a = SampleReservoir::capped(64, 7);
        let mut b = SampleReservoir::capped(64, 7);
        let mut sum = 0.0;
        for i in 0..10_000 {
            let v = (i as f64).sin().abs();
            a.push(v);
            b.push(v);
            sum += v;
        }
        assert!(!a.is_exact());
        assert_eq!(a.samples().len(), 64);
        assert_eq!(a.seen(), 10_000);
        assert_eq!(a.sum().to_bits(), sum.to_bits(), "sum must stay exact past the cap");
        assert_eq!(a, b, "capped reservoir drifted across identical replays");
        // A different seed retains a different subset (overwhelmingly).
        let mut c = SampleReservoir::capped(64, 8);
        for i in 0..10_000 {
            c.push((i as f64).sin().abs());
        }
        assert_ne!(a.samples(), c.samples());
        // clear() resets the window but keeps replaying deterministically.
        a.clear();
        assert_eq!((a.seen(), a.samples().len()), (0, 0));
        assert_eq!(a.sum(), 0.0);
    }

    #[test]
    fn reward_tracker_accumulates() {
        let mut r = RewardTracker::default();
        r.push(1.0, 0.5);
        r.push(2.0, 0.7);
        assert!((r.final_reward() - 1.2).abs() < 1e-9);
        assert!((r.reward_at(1.5) - 0.5).abs() < 1e-9);
        assert_eq!(r.reward_at(0.5), 0.0);
        assert!((r.reward_at(10.0) - 1.2).abs() < 1e-9);
    }
}
