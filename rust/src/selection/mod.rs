//! Workload-aware GMI selection — paper §5.2, Algorithm 2.
//!
//! Profiling-based exploration of (GMIperGPU, num_env): iterate GMI
//! resource budgets from fine (10 per GPU) to coarse (1), sweep `num_env`
//! over powers of two, `profile()` each point (runnable? throughput?
//! memory?), prune with the saturation metric `Sat = R_top / R_mem`, and
//! keep the configuration maximizing the projected system throughput.
//!
//! `profile()` here evaluates the calibrated cost model — the moral
//! equivalent of the paper's short profiling run — so the search is fast
//! and deterministic; the returned configuration then drives real runs.

use crate::config::BenchInfo;
use crate::gmi::GmiBackend;
use crate::vtime::{CostModel, OpKind};

/// Saturation threshold alpha (paper: "generally alpha < 0.1").
pub const SAT_ALPHA: f64 = 0.1;

/// The num_env sweep of Algorithm 2 (128 ... 16384, powers of two).
pub const NUM_ENV_SWEEP: [usize; 8] = [128, 256, 512, 1024, 2048, 4096, 8192, 16384];

/// One profiled design point.
#[derive(Debug, Clone, Copy)]
pub struct ProfilePoint {
    pub gmi_per_gpu: usize,
    pub num_env: usize,
    pub runnable: bool,
    /// env-steps/s of ONE GMI at this configuration.
    pub top: f64,
    /// device memory GiB of one GMI.
    pub mem_gib: f64,
}

/// The selected configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Selection {
    pub num_env: usize,
    pub gmi_per_gpu: usize,
    /// projected aggregate steps/s across all GPUs.
    pub projected_top: f64,
}

/// The SM share ONE of `gmi_per_gpu` co-resident GMIs effectively holds:
/// the quantized fair split, capped at the raw fair share (quantizing UP
/// would let co-residents oversubscribe the GPU), but never below the
/// backend's smallest provisionable partition — a backend cannot hand out
/// less than its granularity floor, so modeling a sub-floor share would
/// bypass the quantization it exists to represent.
pub fn effective_share(backend: GmiBackend, gmi_per_gpu: usize) -> f64 {
    let raw = 1.0 / gmi_per_gpu as f64;
    backend.quantize_share(raw).min(raw).max(backend.min_quantized_share())
}

/// The `profile(DRL_bench, GMIperGPU, num_env)` primitive: evaluate one GMI
/// running the full training pipeline at `1/gmi_per_gpu` of a GPU.
pub fn profile(
    _bench: &BenchInfo,
    cost: &CostModel,
    backend: GmiBackend,
    gmi_per_gpu: usize,
    num_env: usize,
    horizon: usize,
) -> ProfilePoint {
    let share = effective_share(backend, gmi_per_gpu);
    let inter = backend.interference(gmi_per_gpu - 1, cost.heaviness);
    let mem = cost.mem_gib(num_env, horizon, true, true);
    // Runnable: the GMI's memory quota (MIG) or a fair share of the GPU
    // (MPS oversubscription crashes, modeled as a fair-share budget), and a
    // minimum share floor for the runtime itself.
    let quota = backend
        .mem_quota_gib(share)
        .unwrap_or(crate::cluster::A100_MEM_GIB / gmi_per_gpu as f64);
    let runnable = mem <= quota && share >= 0.05;
    if !runnable {
        return ProfilePoint { gmi_per_gpu, num_env, runnable, top: 0.0, mem_gib: mem };
    }
    // One training iteration of this GMI.
    let t_sim = cost.op_time(OpKind::SimStep { num_env }, share, inter);
    let t_fwd = cost.op_time(OpKind::PolicyFwd { num_env }, share, inter);
    let t_train = cost.op_time(
        OpKind::TrainGrad { samples: num_env * horizon },
        share,
        inter,
    );
    let iter_s = horizon as f64 * (t_sim + t_fwd) + t_train;
    let top = (horizon * num_env) as f64 / iter_s;
    ProfilePoint { gmi_per_gpu, num_env, runnable, top, mem_gib: mem }
}

/// `estimate(GMIperGPU, num_GPU, top)`: project single-GMI throughput to
/// the whole system, with a mild comm deduction for cross-GPU sync that
/// grows with the trainer count.
pub fn estimate(gmi_per_gpu: usize, num_gpu: usize, top: f64) -> f64 {
    let total = (gmi_per_gpu * num_gpu) as f64;
    let comm_eff = 1.0 / (1.0 + 0.01 * total.ln_1p());
    top * total * comm_eff
}

/// Algorithm 2: returns the best (num_env, GMIperGPU) plus the search trace
/// (every profiled point, for the gmi_search example / tests).
pub fn explore(
    bench: &BenchInfo,
    cost: &CostModel,
    backend: GmiBackend,
    num_gpu: usize,
    horizon: usize,
) -> (Option<Selection>, Vec<ProfilePoint>) {
    let mut best: Option<Selection> = None;
    let mut trace = Vec::new();

    for gmi_per_gpu in (1..=10).rev() {
        let mut pre_top = 0.0f64;
        let mut pre_mem = 0.0f64;
        for &num_env in NUM_ENV_SWEEP.iter() {
            let p = profile(bench, cost, backend, gmi_per_gpu, num_env, horizon);
            trace.push(p);
            // Filter out non-runnable GMIs.
            if !p.runnable {
                continue;
            }
            // Initialize tracking variables.
            if pre_top == 0.0 && pre_mem == 0.0 {
                pre_top = p.top;
                pre_mem = p.mem_gib;
                // (still consider this first runnable point for the best)
                let acc = estimate(gmi_per_gpu, num_gpu, p.top);
                if best.map(|b| acc > b.projected_top).unwrap_or(true) {
                    best = Some(Selection { num_env, gmi_per_gpu, projected_top: acc });
                }
                continue;
            }
            // Compute performance/resource changes.
            let r_top = (p.top - pre_top) / pre_top;
            let r_mem = (p.mem_gib - pre_mem) / pre_mem;
            let sat = if r_mem.abs() > 1e-12 { r_top / r_mem } else { f64::INFINITY };
            pre_top = p.top;
            pre_mem = p.mem_gib;
            // Check if the performance saturates (early stop).
            if sat < SAT_ALPHA {
                break;
            }
            // Project the overall system throughput.
            let acc = estimate(gmi_per_gpu, num_gpu, p.top);
            if best.map(|b| acc > b.projected_top).unwrap_or(true) {
                best = Some(Selection { num_env, gmi_per_gpu, projected_top: acc });
            }
        }
    }
    (best, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::static_registry;

    fn at() -> (BenchInfo, CostModel) {
        let b = static_registry()["AT"].clone();
        let c = CostModel::new(&b);
        (b, c)
    }

    #[test]
    fn profile_point_sanity() {
        let (b, c) = at();
        let p = profile(&b, &c, GmiBackend::Mps, 4, 2048, 16);
        assert!(p.runnable);
        assert!(p.top > 0.0);
        assert!(p.mem_gib > 0.0);
    }

    #[test]
    fn oversized_env_count_not_runnable() {
        let (b, c) = at();
        // 16384 envs on a 1/10-GPU GMI exceeds its fair memory budget.
        let p = profile(&b, &c, GmiBackend::Mps, 10, 16384, 16);
        assert!(!p.runnable, "mem {} should not fit", p.mem_gib);
    }

    #[test]
    fn throughput_saturates_with_num_env() {
        // Fig 10's shape: doubling num_env stops paying at some point.
        let (b, c) = at();
        let t1 = profile(&b, &c, GmiBackend::Mps, 1, 2048, 16).top;
        let t2 = profile(&b, &c, GmiBackend::Mps, 1, 4096, 16).top;
        let t3 = profile(&b, &c, GmiBackend::Mps, 1, 8192, 16).top;
        assert!(t2 > t1);
        let gain_12 = t2 / t1;
        let gain_23 = t3 / t2;
        assert!(gain_23 < gain_12, "diminishing returns: {gain_12} then {gain_23}");
    }

    #[test]
    fn explore_finds_multiplexed_config() {
        // The headline: the search must prefer multiple GMIs per GPU over
        // one exclusive process.
        let (b, c) = at();
        let (best, trace) = explore(&b, &c, GmiBackend::Mps, 4, 16);
        let best = best.expect("search found nothing");
        assert!(best.gmi_per_gpu > 1, "expected multiplexing, got {best:?}");
        assert!(best.num_env >= 128);
        assert!(!trace.is_empty());
        // the projection beats the best single-process config
        let single_best = trace
            .iter()
            .filter(|p| p.gmi_per_gpu == 1 && p.runnable)
            .map(|p| estimate(1, 4, p.top))
            .fold(0.0f64, f64::max);
        assert!(best.projected_top > single_best);
    }

    #[test]
    fn explore_deterministic() {
        let (b, c) = at();
        let (b1, t1) = explore(&b, &c, GmiBackend::Mps, 2, 16);
        let (b2, t2) = explore(&b, &c, GmiBackend::Mps, 2, 16);
        assert_eq!(b1, b2);
        assert_eq!(t1.len(), t2.len());
    }

    #[test]
    fn estimate_monotone_in_gmis() {
        assert!(estimate(4, 4, 100.0) > estimate(2, 4, 100.0));
        assert!(estimate(4, 8, 100.0) > estimate(4, 4, 100.0));
    }

    #[test]
    fn profile_runnable_boundary_cases() {
        let (b, c) = at();
        // The finest budget Algorithm 2 sweeps (1/10 GPU) still clears the
        // 5% share floor for MPS; a small env count there is runnable.
        let p = profile(&b, &c, GmiBackend::Mps, 10, 128, 16);
        assert!(p.runnable, "1/10-GPU GMI at 128 envs must run");
        // MIG quantizes the same budget UP to a 1g.5gb profile, so it is
        // runnable too — until its 5 GiB memory quota caps env growth.
        let mig_small = profile(&b, &c, GmiBackend::Mig, 10, 128, 16);
        assert!(mig_small.runnable);
        let mig_big = profile(&b, &c, GmiBackend::Mig, 10, 16384, 16);
        assert!(!mig_big.runnable, "1g.5gb cannot hold 16k envs ({} GiB)", mig_big.mem_gib);
        // Non-runnable points report zero throughput, never garbage.
        assert_eq!(mig_big.top, 0.0);
        assert!(mig_big.mem_gib > 5.0);
    }

    #[test]
    fn high_gmi_per_gpu_clamps_to_backend_granularity_floor() {
        // Regression for the old `<= 0.0 -> raw 1/gmi_per_gpu` fallback:
        // the profiled share must never drop below what the backend can
        // provision. At 20 GMIs/GPU the fair split (0.05) is under MIG's
        // smallest partition (1g.5gb = 1/7); both 14- and 20-way splits
        // land on that same slice, so their single-GMI profiles (MIG has
        // no co-residency interference) must be identical — the old code
        // modeled a phantom 0.05-share instance instead.
        let (b, c) = at();
        assert!((effective_share(GmiBackend::Mig, 20) - 1.0 / 7.0).abs() < 1e-12);
        assert!((effective_share(GmiBackend::Mig, 14) - 1.0 / 7.0).abs() < 1e-12);
        let p20 = profile(&b, &c, GmiBackend::Mig, 20, 128, 16);
        let p14 = profile(&b, &c, GmiBackend::Mig, 14, 128, 16);
        assert!(p20.runnable && p14.runnable);
        assert_eq!(p20.top, p14.top, "both land on 1g.5gb; share clamps to 1/7");
        // MPS's floor is one percentage point: a 200-way split models the
        // 1% floor (not raw 0.005) and stays below the runtime share floor.
        assert!((effective_share(GmiBackend::Mps, 200) - 0.01).abs() < 1e-12);
        assert!(!profile(&b, &c, GmiBackend::Mps, 200, 128, 16).runnable);
        // Where quantization is exact the clamp is a no-op.
        assert!((effective_share(GmiBackend::Mps, 4) - 0.25).abs() < 1e-12);
        assert!((effective_share(GmiBackend::DirectShare, 8) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn profile_throughput_monotone_in_share_budget() {
        // Strategy-selection edge: fewer GMIs per GPU = more share each;
        // a single GMI's throughput must never DROP when its budget grows
        // (saturation flattens it, but never inverts it).
        let (b, c) = at();
        let mut prev = 0.0;
        for gmi_per_gpu in (1..=8).rev() {
            let p = profile(&b, &c, GmiBackend::Mps, gmi_per_gpu, 1024, 16);
            if !p.runnable {
                continue;
            }
            assert!(
                p.top + 1e-9 >= prev,
                "throughput dropped when share grew: {} then {} at 1/{}",
                prev,
                p.top,
                gmi_per_gpu
            );
            prev = p.top;
        }
        assert!(prev > 0.0);
    }

    #[test]
    fn explore_single_gpu_and_single_point_edges() {
        let (b, c) = at();
        // One GPU: the search still returns a runnable multiplexed config.
        let (best, trace) = explore(&b, &c, GmiBackend::Mps, 1, 16);
        let best = best.expect("1-GPU search found nothing");
        assert!(best.gmi_per_gpu >= 1 && best.num_env >= 128);
        assert!(trace.iter().any(|p| p.runnable));
        // The selected point is present in the trace and runnable there.
        assert!(trace
            .iter()
            .any(|p| p.runnable
                && p.gmi_per_gpu == best.gmi_per_gpu
                && p.num_env == best.num_env));
        // The projection is consistent with its own profile point.
        let pt = profile(&b, &c, GmiBackend::Mps, best.gmi_per_gpu, best.num_env, 16);
        let want = estimate(best.gmi_per_gpu, 1, pt.top);
        assert!((best.projected_top - want).abs() < 1e-6 * want.max(1.0));
    }

    #[test]
    fn saturation_pruning_skips_flat_tail_points() {
        // The Sat < alpha early-stop must actually prune: for some GMI
        // budget the sweep stops before the largest env count, so the
        // trace holds fewer points than the full grid.
        let (b, c) = at();
        let (_, trace) = explore(&b, &c, GmiBackend::Mps, 4, 16);
        let full_grid = 10 * NUM_ENV_SWEEP.len();
        assert!(
            trace.len() < full_grid,
            "no pruning happened: {} == {} grid points",
            trace.len(),
            full_grid
        );
    }
}
