//! Specialized GMI communication (paper §4).
//!
//! GPU spatial multiplexing erects memory barriers between GMIs, so the
//! stock GPU-granularity primitives (NCCL, CUDA IPC) don't apply at the
//! sub-GPU granularity. This module provides the paper's two answers:
//!
//! * [`lgr`] — latency-optimized **layout-aware gradient reduction** for
//!   synchronized training (§4.1): MPR / MRR / HAR + Algorithm 1 selection.
//! * p2p transfer primitives used by the throughput-optimized
//!   channel-based experience sharing (§4.2, see the `channels` module).
//!
//! All reductions do *real arithmetic* on the gradient vectors (bit-checked
//! by tests); the *time* comes from transfer plans lowered by the
//! communication [`fabric`](crate::fabric) over the `cluster` link model —
//! this module computes no link costs of its own.

pub mod lgr;
pub mod multinode;

pub use lgr::{select_strategy, LgrEngine, ReduceStrategy};
pub use multinode::{MultiNodeLgr, MultiNodeTopology};

/// Sum `srcs` element-wise into a fresh vector (the arithmetic every
/// reduction strategy must produce, regardless of routing).
///
/// Blocked over columns so the destination block stays in L1/L2 while all
/// sources stream through it once — on SH-sized gradients (16 x 6 MB) this
/// is ~3x faster than source-major accumulation, which re-reads the full
/// destination per source (EXPERIMENTS.md §Perf, L3 iteration 1).
pub fn reduce_sum(srcs: &[&[f32]]) -> Vec<f32> {
    assert!(!srcs.is_empty());
    let n = srcs[0].len();
    for s in srcs {
        assert_eq!(s.len(), n, "gradient length mismatch");
    }
    const BLOCK: usize = 4096; // 16 KiB destination block
    let mut out = vec![0.0f32; n];
    let mut start = 0;
    while start < n {
        let end = (start + BLOCK).min(n);
        let dst = &mut out[start..end];
        for s in srcs {
            let src = &s[start..end];
            for (o, v) in dst.iter_mut().zip(src.iter()) {
                *o += v;
            }
        }
        start = end;
    }
    out
}

/// Average variant (gradient allreduce convention for data parallelism).
pub fn reduce_mean(srcs: &[&[f32]]) -> Vec<f32> {
    let mut out = reduce_sum(srcs);
    let k = srcs.len() as f32;
    for o in out.iter_mut() {
        *o /= k;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_and_mean() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![3.0f32, 2.0, 1.0];
        let s = reduce_sum(&[&a, &b]);
        assert_eq!(s, vec![4.0, 4.0, 4.0]);
        let m = reduce_mean(&[&a, &b]);
        assert_eq!(m, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        let a = vec![1.0f32; 3];
        let b = vec![1.0f32; 4];
        reduce_sum(&[&a, &b]);
    }
}
