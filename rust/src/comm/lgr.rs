//! Layout-aware gradient reduction (LGR) — paper §4.1, Figure 4, Table 2,
//! Algorithm 1.
//!
//! Three strategies for allreducing trainer gradients across GMIs:
//!
//! * **MPR** (Multi-Process Reduction): every GMI stages its gradient to
//!   host memory, the CPU reduces, results broadcast back. Generic — works
//!   for any layout — but hammers the PCIe paths and the slow CPU.
//! * **MRR** (Multi-Ring Reduction): GMIs at the same intra-GPU ordinal
//!   form non-intersecting NCCL rings across GPUs (NCCL *can* run between
//!   GMIs on different GPUs, just not within one); a final ring merges the
//!   per-ring partials. Only valid when t <= g, otherwise the final ring
//!   would need two endpoints on one GPU ("multiple CUDA streams error").
//! * **HAR** (Hierarchical Reduction): host-staged reduce *within* each GPU
//!   (leader GMI per GPU: `GMI_id % M == t`), NCCL ring across the g
//!   leaders, broadcast back down. Combines both levels.
//!
//! Every strategy executes the *real* reduction arithmetic (bit-checked by
//! tests); the *time* is a transfer plan lowered by the communication
//! [`fabric`](crate::fabric) — this module holds no link math of its own.
//! [`select_strategy`] is the paper's Algorithm 1 layout heuristic;
//! [`LgrEngine::cheapest_strategy`] is the fabric planner's cost-based
//! replacement (validated against the heuristic by the property tests).

use anyhow::{bail, Result};

use super::reduce_mean;
use crate::cluster::Topology;
use crate::fabric::{Fabric, Plan};

pub use crate::fabric::ReduceStrategy;

/// Algorithm 1: pick the strategy from the GMI-to-GPU mapping list `MPL`
/// (one inner vec of GMI ids per GPU).
pub fn select_strategy(mpl: &[Vec<usize>]) -> ReduceStrategy {
    // All GMIs on the same GPU -> MPR.
    if mpl.len() <= 1 {
        return ReduceStrategy::MultiProcess;
    }
    // Different GPUs host different numbers of GMIs -> HAR.
    let mut sizes: Vec<usize> = mpl.iter().map(|v| v.len()).collect();
    sizes.dedup();
    if sizes.len() > 1 {
        return ReduceStrategy::Hierarchical;
    }
    // More GMIs per GPU than GPUs -> the final MRR ring would need multiple
    // endpoints on one GPU -> HAR.
    if mpl[0].len() > mpl.len() {
        return ReduceStrategy::Hierarchical;
    }
    ReduceStrategy::MultiRing
}

/// Table 2 analytical time complexities (for the table2 bench and the cost
/// cross-check test). `g` GPUs, `t` GMIs/GPU, `mp` parameter bytes, `b1`
/// inter-GMI host bandwidth, `b2` NCCL bandwidth.
pub mod analytical {
    pub fn mpr(g: usize, t: usize, mp: f64, b1: f64) -> f64 {
        let gt = (g * t) as f64;
        2.0 * (gt - 1.0) * mp / (gt * b1)
    }

    pub fn mrr(g: usize, t: usize, mp: f64, b2: f64) -> f64 {
        let g_ = g as f64;
        2.0 * (g_ - 1.0) * (t as f64 + 1.0) * mp / (g_ * b2)
    }

    pub fn har(g: usize, t: usize, mp: f64, b1: f64, b2: f64) -> f64 {
        let (g_, t_) = (g as f64, t as f64);
        2.0 * (g_ - 1.0) * mp / (g_ * b2) + 2.0 * (t_ - 1.0) * mp / (t_ * b1)
    }
}

/// The LGR engine: owns the layout (mapping list) and executes reductions,
/// with all routing costs lowered through the communication fabric.
pub struct LgrEngine {
    fabric: Fabric,
    /// `mpl[i]` = GMI ids on GPU i (trainer GMIs only).
    mpl: Vec<Vec<usize>>,
}

impl LgrEngine {
    pub fn new(topology: Topology, mpl: Vec<Vec<usize>>) -> Result<Self> {
        if mpl.is_empty() || mpl.iter().any(|v| v.is_empty()) {
            bail!("empty GMI mapping list");
        }
        if mpl.len() > topology.num_gpus() {
            bail!("mapping list has {} GPUs, topology {}", mpl.len(), topology.num_gpus());
        }
        Ok(LgrEngine { fabric: Fabric::single_node(topology), mpl })
    }

    pub fn num_gmis(&self) -> usize {
        self.mpl.iter().map(|v| v.len()).sum()
    }

    pub fn num_gpus(&self) -> usize {
        self.mpl.len()
    }

    /// Algorithm 1's heuristic pick for this layout.
    pub fn strategy(&self) -> ReduceStrategy {
        select_strategy(&self.mpl)
    }

    /// The planner's pick: the cheapest valid plan for `bytes` under the
    /// fabric cost model (never an invalid MRR, never costlier than the
    /// Algorithm 1 heuristic's choice).
    pub fn cheapest_strategy(&self, bytes: usize) -> ReduceStrategy {
        self.fabric.cheapest_allreduce(&self.mpl, bytes).0
    }

    /// Lower one reduction of `bytes` under `strategy` into a fabric plan
    /// (for callers that execute it as an engine event).
    pub fn plan(&self, bytes: usize, strategy: ReduceStrategy) -> Result<Plan> {
        self.fabric.plan_allreduce(&self.mpl, bytes, strategy)
    }

    /// Allreduce (mean) the per-GMI gradients, flattened in mapping-list
    /// order. Returns (reduced gradient, virtual seconds of the chosen
    /// routing). Includes the final broadcast back to all GMIs.
    pub fn allreduce(&self, grads: &[Vec<f32>], strategy: ReduceStrategy) -> Result<(Vec<f32>, f64)> {
        let n = self.num_gmis();
        if grads.len() != n {
            bail!("expected {n} gradients, got {}", grads.len());
        }
        if n == 1 {
            return Ok((grads[0].clone(), 0.0));
        }
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let reduced = reduce_mean(&refs);
        let time = self.reduce_time(4 * grads[0].len(), strategy)?;
        Ok((reduced, time))
    }

    /// Virtual cost of one reduction of `bytes` under `strategy` (the
    /// timing half of `allreduce`, for callers that charge several
    /// minibatch reductions against one materialized gradient).
    pub fn reduce_time(&self, bytes: usize, strategy: ReduceStrategy) -> Result<f64> {
        if self.num_gmis() == 1 {
            return Ok(0.0);
        }
        Ok(self.plan(bytes, strategy)?.total_s())
    }

    pub fn mapping_list(&self) -> &[Vec<usize>] {
        &self.mpl
    }

    /// Leader GMI of each GPU under HAR: `GMI_id % M == t` rule of §4.1
    /// (we take the first GMI of each GPU's list, which satisfies the
    /// round-robin id layout the paper assumes).
    pub fn leaders(&self) -> Vec<usize> {
        self.mpl.iter().map(|v| v[0]).collect()
    }

    /// NCCL's constraint check: a ring may touch each GPU at most once.
    pub fn validate_ring(&self, ring: &[usize]) -> bool {
        let mut gpus = Vec::new();
        for gmi in ring {
            let Some(gpu) = self.gpu_of(*gmi) else { return false };
            if gpus.contains(&gpu) {
                return false;
            }
            gpus.push(gpu);
        }
        true
    }

    fn gpu_of(&self, gmi: usize) -> Option<usize> {
        self.mpl.iter().position(|v| v.contains(&gmi))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{HOST_BW, NVLINK_BW};

    fn mpl(g: usize, t: usize) -> Vec<Vec<usize>> {
        (0..g).map(|i| (0..t).map(|j| i * t + j).collect()).collect()
    }

    #[test]
    fn algorithm1_selection() {
        // All GMIs on one GPU -> MPR.
        assert_eq!(select_strategy(&mpl(1, 3)), ReduceStrategy::MultiProcess);
        // Unequal counts -> HAR.
        assert_eq!(
            select_strategy(&[vec![0, 1], vec![2]]),
            ReduceStrategy::Hierarchical
        );
        // t > g -> HAR (paper: 2 GPUs, 3 trainers each).
        assert_eq!(select_strategy(&mpl(2, 3)), ReduceStrategy::Hierarchical);
        // t <= g with equal counts -> MRR.
        assert_eq!(select_strategy(&mpl(4, 4)), ReduceStrategy::MultiRing);
        assert_eq!(select_strategy(&mpl(4, 2)), ReduceStrategy::MultiRing);
    }

    #[test]
    fn all_strategies_same_arithmetic() {
        let topo = Topology::dgx_a100(4);
        let engine = LgrEngine::new(topo, mpl(4, 2)).unwrap();
        let grads: Vec<Vec<f32>> = (0..8)
            .map(|i| (0..64).map(|j| (i * 64 + j) as f32 * 0.01).collect())
            .collect();
        let (a, _) = engine.allreduce(&grads, ReduceStrategy::MultiProcess).unwrap();
        let (b, _) = engine.allreduce(&grads, ReduceStrategy::MultiRing).unwrap();
        let (c, _) = engine.allreduce(&grads, ReduceStrategy::Hierarchical).unwrap();
        assert_eq!(a, b);
        assert_eq!(b, c);
        // Check against a hand-rolled mean.
        let want: Vec<f32> = (0..64)
            .map(|j| (0..8).map(|i| (i * 64 + j) as f32 * 0.01).sum::<f32>() / 8.0)
            .collect();
        for (x, y) in a.iter().zip(want.iter()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn har_beats_mpr_on_multi_gpu_layouts() {
        // Table 7's premise: on 4G4T the hierarchical strategy wins clearly.
        let topo = Topology::dgx_a100(4);
        let engine = LgrEngine::new(topo, mpl(4, 4)).unwrap();
        let grads: Vec<Vec<f32>> = (0..16).map(|_| vec![0.5f32; 1_500_000]).collect();
        let (_, t_mpr) = engine.allreduce(&grads, ReduceStrategy::MultiProcess).unwrap();
        let (_, t_har) = engine.allreduce(&grads, ReduceStrategy::Hierarchical).unwrap();
        assert!(t_har < t_mpr, "HAR {t_har} vs MPR {t_mpr}");
        assert!(t_mpr / t_har > 1.5, "expected clear HAR win, got {}", t_mpr / t_har);
    }

    #[test]
    fn mrr_between_mpr_and_nothing() {
        let topo = Topology::dgx_a100(4);
        let engine = LgrEngine::new(topo, mpl(4, 2)).unwrap();
        let grads: Vec<Vec<f32>> = (0..8).map(|_| vec![0.5f32; 1_500_000]).collect();
        let (_, t_mpr) = engine.allreduce(&grads, ReduceStrategy::MultiProcess).unwrap();
        let (_, t_mrr) = engine.allreduce(&grads, ReduceStrategy::MultiRing).unwrap();
        assert!(t_mrr < t_mpr, "MRR {t_mrr} vs MPR {t_mpr}");
    }

    #[test]
    fn mrr_rejects_t_greater_g() {
        let topo = Topology::dgx_a100(2);
        let engine = LgrEngine::new(topo, mpl(2, 3)).unwrap();
        let grads: Vec<Vec<f32>> = (0..6).map(|_| vec![1.0f32; 16]).collect();
        assert!(engine.allreduce(&grads, ReduceStrategy::MultiRing).is_err());
    }

    #[test]
    fn single_gmi_is_free() {
        let topo = Topology::dgx_a100(1);
        let engine = LgrEngine::new(topo, mpl(1, 1)).unwrap();
        let grads = vec![vec![1.0f32, 2.0]];
        let (r, t) = engine.allreduce(&grads, ReduceStrategy::MultiProcess).unwrap();
        assert_eq!(r, vec![1.0, 2.0]);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn wrong_grad_count_rejected() {
        let topo = Topology::dgx_a100(2);
        let engine = LgrEngine::new(topo, mpl(2, 2)).unwrap();
        let grads = vec![vec![1.0f32; 4]; 3];
        assert!(engine.allreduce(&grads, ReduceStrategy::Hierarchical).is_err());
    }

    #[test]
    fn ring_validation() {
        let topo = Topology::dgx_a100(3);
        let engine = LgrEngine::new(topo, mpl(3, 2)).unwrap();
        // one GMI per GPU: valid ring
        assert!(engine.validate_ring(&[0, 2, 4]));
        // two GMIs of GPU 0: invalid
        assert!(!engine.validate_ring(&[0, 1, 2]));
        // unknown GMI: invalid
        assert!(!engine.validate_ring(&[0, 99]));
    }

    #[test]
    fn analytical_formulas_ordering() {
        // Table 2 at the paper's own operating point: HAR <= MRR <= MPR for
        // multi-GPU multi-GMI layouts with B2 >> B1.
        let mp = 1.5e6 * 4.0;
        let mpr = analytical::mpr(4, 4, mp, HOST_BW);
        let mrr = analytical::mrr(4, 4, mp, NVLINK_BW);
        let har = analytical::har(4, 4, mp, HOST_BW, NVLINK_BW);
        assert!(har < mpr, "har {har} mpr {mpr}");
        assert!(mrr < mpr, "mrr {mrr} mpr {mpr}");
    }

    #[test]
    fn leaders_one_per_gpu() {
        let topo = Topology::dgx_a100(4);
        let engine = LgrEngine::new(topo, mpl(4, 3)).unwrap();
        assert_eq!(engine.leaders(), vec![0, 3, 6, 9]);
    }

    #[test]
    fn cheapest_never_costlier_than_algorithm1() {
        for (g, t) in [(1usize, 3usize), (2, 2), (2, 3), (4, 2), (4, 4), (8, 3)] {
            let engine = LgrEngine::new(Topology::dgx_a100(g), mpl(g, t)).unwrap();
            let bytes = 6 << 20;
            let cheap = engine.cheapest_strategy(bytes);
            let t_cheap = engine.reduce_time(bytes, cheap).unwrap();
            let t_alg1 = engine.reduce_time(bytes, engine.strategy()).unwrap();
            assert!(t_cheap <= t_alg1 + 1e-15, "{g}G{t}T: {cheap} {t_cheap} vs {t_alg1}");
        }
    }
}
