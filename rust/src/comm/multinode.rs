//! Multi-node extension of layout-aware gradient reduction (paper §8, "For
//! DRL scaling": *"our layout-aware gradient reduction technique can be
//! extended to support efficient multi-node model synchronization by
//! considering the intra- and inter-node GMI layout hierarchy"*).
//!
//! Three-level hierarchy:
//!   1. intra-GPU:  host-staged reduce to a per-GPU leader (as HAR step 1);
//!   2. intra-node: NCCL ring over the node's GPU leaders via NVLink;
//!   3. inter-node: ring over per-node leaders via InfiniBand.
//! Then broadcast back down the same tree.
//!
//! The routing costs are lowered by the communication
//! [`fabric`](crate::fabric) (the hierarchy is
//! [`Fabric::plan_multinode_allreduce`]; the flat ablation is
//! [`Fabric::plan_flat_mpr`]); this module owns the layout validation and
//! the real reduction arithmetic.
//!
//! [`Fabric::plan_multinode_allreduce`]: crate::fabric::Fabric::plan_multinode_allreduce
//! [`Fabric::plan_flat_mpr`]: crate::fabric::Fabric::plan_flat_mpr

use anyhow::{bail, Result};

use super::reduce_mean;
use crate::fabric::Fabric;

pub use crate::cluster::{MultiNodeTopology, IB_BW, IB_LAT};

/// Hierarchical multi-node reducer: `t` trainer GMIs per GPU, `g` GPUs per
/// node, `nodes` nodes.
pub struct MultiNodeLgr {
    fabric: Fabric,
    g: usize,
    t: usize,
}

impl MultiNodeLgr {
    pub fn new(topo: MultiNodeTopology, gpus_per_node: usize, gmis_per_gpu: usize) -> Result<Self> {
        if gpus_per_node == 0 || gmis_per_gpu == 0 {
            bail!("empty layout");
        }
        if gpus_per_node > topo.node.num_gpus() {
            bail!("node has {} GPUs, asked {gpus_per_node}", topo.node.num_gpus());
        }
        Ok(MultiNodeLgr { fabric: Fabric::multi_node(topo), g: gpus_per_node, t: gmis_per_gpu })
    }

    pub fn num_gmis(&self) -> usize {
        self.fabric.multi_topology().expect("multi-node fabric").num_nodes * self.g * self.t
    }

    /// Allreduce (mean) over all GMIs' gradients, flattened node-major.
    /// Returns (reduced gradient, virtual seconds of the 3-level routing).
    pub fn allreduce(&self, grads: &[Vec<f32>]) -> Result<(Vec<f32>, f64)> {
        let n = self.num_gmis();
        if grads.len() != n {
            bail!("expected {n} gradients, got {}", grads.len());
        }
        if n == 1 {
            return Ok((grads[0].clone(), 0.0));
        }
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let reduced = reduce_mean(&refs);
        let time = self.reduce_time(4 * grads[0].len());
        Ok((reduced, time))
    }

    /// Cost of the 3-level hierarchy for one reduction of `bytes`.
    pub fn reduce_time(&self, bytes: usize) -> f64 {
        self.fabric.plan_multinode_allreduce(self.g, self.t, bytes).total_s()
    }

    /// The naive flat alternative: a ring over all GMIs is *invalid*
    /// (multiple endpoints per GPU — the same "multiple CUDA streams"
    /// constraint as single-node MRR), so the only layout-oblivious option
    /// at scale is MPR: every GMI host-stages to a global CPU reduction.
    /// Used by tests/ablation to show the hierarchy is required at scale.
    pub fn flat_mpr_time(&self, bytes: usize) -> f64 {
        self.fabric.plan_flat_mpr(self.g, self.t, bytes).total_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grads(n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|i| (0..len).map(|j| (i * len + j) as f32 * 1e-3).collect())
            .collect()
    }

    #[test]
    fn arithmetic_matches_flat_mean() {
        let topo = MultiNodeTopology::dgx_cluster(2, 2);
        let lgr = MultiNodeLgr::new(topo, 2, 2).unwrap();
        let g = grads(8, 32);
        let (got, secs) = lgr.allreduce(&g).unwrap();
        let refs: Vec<&[f32]> = g.iter().map(|v| v.as_slice()).collect();
        assert_eq!(got, reduce_mean(&refs));
        assert!(secs > 0.0);
    }

    #[test]
    fn hierarchy_beats_flat_mpr_at_scale() {
        // 4 nodes x 8 GPUs x 4 GMIs = 128 GMIs, SH-sized model.
        let topo = MultiNodeTopology::dgx_cluster(4, 8);
        let lgr = MultiNodeLgr::new(topo, 8, 4).unwrap();
        let bytes = 6 * 1024 * 1024;
        let hier = lgr.reduce_time(bytes);
        let flat = lgr.flat_mpr_time(bytes);
        assert!(
            flat / hier > 4.0,
            "hierarchy {hier}s vs flat MPR {flat}s should win clearly"
        );
    }

    #[test]
    fn single_node_reduces_to_har() {
        // With 1 node the level-3 term vanishes; cost ~ HAR of the node.
        let topo = MultiNodeTopology::dgx_cluster(1, 4);
        let lgr = MultiNodeLgr::new(topo.clone(), 4, 2).unwrap();
        let with_l3 = MultiNodeLgr::new(
            MultiNodeTopology { node: topo.node.clone(), num_nodes: 2 },
            4,
            2,
        )
        .unwrap();
        let bytes = 1 << 20;
        assert!(lgr.reduce_time(bytes) < with_l3.reduce_time(bytes));
    }

    #[test]
    fn cost_scales_sublinearly_in_nodes() {
        // Ring allreduce: 2(k-1)/k -> time approaches 2x bytes/IB_BW, not
        // linear in node count.
        let bytes = 4 << 20;
        let t2 = MultiNodeLgr::new(MultiNodeTopology::dgx_cluster(2, 4), 4, 2)
            .unwrap()
            .reduce_time(bytes);
        let t8 = MultiNodeLgr::new(MultiNodeTopology::dgx_cluster(8, 4), 4, 2)
            .unwrap()
            .reduce_time(bytes);
        assert!(t8 < t2 * 2.0, "t2={t2} t8={t8}");
    }

    #[test]
    fn rejects_bad_layouts() {
        let topo = MultiNodeTopology::dgx_cluster(2, 4);
        assert!(MultiNodeLgr::new(topo.clone(), 0, 2).is_err());
        assert!(MultiNodeLgr::new(topo.clone(), 5, 2).is_err());
        let lgr = MultiNodeLgr::new(topo, 2, 2).unwrap();
        assert!(lgr.allreduce(&grads(3, 8)).is_err());
    }
}
