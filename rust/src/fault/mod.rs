//! Seeded failure injection and the cluster fault model (ROADMAP item:
//! "survive a 16-node day"; paper §8 direction).
//!
//! A [`FaultTrace`] is a time-sorted list of [`FaultEvent`]s — GPU, node,
//! NVSwitch, or InfiniBand failures and (optionally) repairs — produced
//! either by the seeded generator ([`FaultTrace::generate`], a splitmix64
//! stream with exponential inter-arrival times, bit-reproducible for a
//! given [`FaultTraceConfig`]) or parsed from a declarative trace file
//! ([`FaultTrace::parse`], `"<t_s> fail|repair gpu <i>|node <i>|nvswitch|ib"`
//! lines).
//!
//! The scheduler consumes a trace through a [`FaultPlan`]
//! ([`SchedConfig::faults`](crate::sched::SchedConfig)): events due at a
//! round boundary are applied to the shared [`Fabric`] (marking links and
//! GPUs out of service — the planner then reroutes or reports a partition),
//! tenants with members on dead GPUs are killed and re-queued, and —
//! when `checkpoint_interval_s` is finite — running tenants are
//! periodically checkpointed via [`Workload::snapshot`]
//! (crate::workload::Workload::snapshot), with the capture cost charged to
//! the tenant's own executors in virtual time, so a killed tenant restarts
//! from its last checkpoint instead of from scratch.

use anyhow::{bail, Context, Result};

use crate::fabric::Fabric;

/// What fails (or recovers). Node indices address `gpus_per_node`-sized
/// contiguous GPU ranges of a flattened cluster topology
/// ([`Topology::flat_cluster`](crate::cluster::Topology::flat_cluster)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTarget {
    Gpu(usize),
    Node(usize),
    NvSwitch,
    Ib,
}

impl std::fmt::Display for FaultTarget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultTarget::Gpu(g) => write!(f, "gpu {g}"),
            FaultTarget::Node(n) => write!(f, "node {n}"),
            FaultTarget::NvSwitch => f.write_str("nvswitch"),
            FaultTarget::Ib => f.write_str("ib"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Fail,
    Repair,
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultKind::Fail => "fail",
            FaultKind::Repair => "repair",
        })
    }
}

/// One scheduled hardware event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub t_s: f64,
    pub kind: FaultKind,
    pub target: FaultTarget,
}

impl FaultEvent {
    /// The GPUs this event takes down (or brings back). Link events return
    /// an empty range.
    pub fn gpus(&self, gpus_per_node: usize, num_gpus: usize) -> std::ops::Range<usize> {
        match self.target {
            FaultTarget::Gpu(g) if g < num_gpus => g..g + 1,
            FaultTarget::Node(n) => {
                let lo = (n * gpus_per_node).min(num_gpus);
                let hi = ((n + 1) * gpus_per_node).min(num_gpus);
                lo..hi
            }
            _ => 0..0,
        }
    }

    /// Mark the event on the fabric: dead GPUs and links invalidate routes
    /// and collective plans until repaired. An `ib` event on a fabric
    /// without an InfiniBand link is a no-op.
    pub fn apply(&self, fabric: &mut Fabric, gpus_per_node: usize) {
        let num_gpus = fabric.topology().num_gpus();
        match self.target {
            FaultTarget::Gpu(_) | FaultTarget::Node(_) => {
                for g in self.gpus(gpus_per_node, num_gpus) {
                    match self.kind {
                        FaultKind::Fail => fabric.fail_gpu(g),
                        FaultKind::Repair => fabric.repair_gpu(g),
                    }
                }
            }
            FaultTarget::NvSwitch => {
                let l = fabric.nvswitch_link();
                match self.kind {
                    FaultKind::Fail => fabric.fail_link(l),
                    FaultKind::Repair => fabric.repair_link(l),
                }
            }
            FaultTarget::Ib => {
                if let Some(l) = fabric.ib_link() {
                    match self.kind {
                        FaultKind::Fail => fabric.fail_link(l),
                        FaultKind::Repair => fabric.repair_link(l),
                    }
                }
            }
        }
    }
}

/// Knobs of the seeded trace generator. Mean-time-between-failure values
/// are per *fleet* (one draw stream per failure class); `f64::INFINITY`
/// disables a class.
#[derive(Debug, Clone, Copy)]
pub struct FaultTraceConfig {
    pub seed: u64,
    /// Trace horizon: no failure is emitted at or past this time.
    pub duration_s: f64,
    pub num_gpus: usize,
    pub gpus_per_node: usize,
    /// Mean virtual seconds between single-GPU failures across the fleet.
    pub gpu_mtbf_s: f64,
    /// Mean virtual seconds between whole-node failures.
    pub node_mtbf_s: f64,
    /// Mean virtual seconds between fabric-link (NVSwitch) failures.
    pub link_mtbf_s: f64,
    /// Mean repair delay after a failure; `None` means nothing recovers.
    pub repair_after_s: Option<f64>,
}

/// A time-sorted hardware event schedule over one run.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultTrace {
    pub events: Vec<FaultEvent>,
    /// Node granularity used to resolve `node` targets on a flattened
    /// cluster topology.
    pub gpus_per_node: usize,
}

// splitmix64 — the repo's dependency-free deterministic RNG idiom.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn unit(state: &mut u64) -> f64 {
    (splitmix64(state) >> 11) as f64 / (1u64 << 53) as f64
}

/// Exponential inter-arrival draw with the given mean (never 0, never inf).
fn exp_draw(state: &mut u64, mean_s: f64) -> f64 {
    -mean_s * (1.0 - unit(state)).max(1e-12).ln()
}

impl FaultTrace {
    /// A trace with the events sorted by time (ties keep insertion order).
    pub fn new(mut events: Vec<FaultEvent>, gpus_per_node: usize) -> Self {
        events.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).expect("fault times are not NaN"));
        FaultTrace { events, gpus_per_node: gpus_per_node.max(1) }
    }

    /// Seeded, deterministic generation: three independent Poisson-ish
    /// streams (GPU / node / NVSwitch-link failures), each an exponential
    /// inter-arrival walk over one splitmix64 stream, targets drawn
    /// uniformly. Identical config ⇒ identical trace, bit-for-bit.
    pub fn generate(cfg: &FaultTraceConfig) -> Self {
        let mut events = Vec::new();
        let mut emit = |mtbf: f64, stream: u64, pick: &mut dyn FnMut(&mut u64) -> FaultTarget| {
            if !mtbf.is_finite() || mtbf <= 0.0 {
                return;
            }
            let mut state = cfg.seed ^ stream;
            let mut t = exp_draw(&mut state, mtbf);
            while t < cfg.duration_s {
                let target = pick(&mut state);
                events.push(FaultEvent { t_s: t, kind: FaultKind::Fail, target });
                if let Some(mean_repair) = cfg.repair_after_s {
                    let back = t + exp_draw(&mut state, mean_repair);
                    if back < cfg.duration_s {
                        events.push(FaultEvent { t_s: back, kind: FaultKind::Repair, target });
                    }
                }
                t += exp_draw(&mut state, mtbf);
            }
        };
        let num_gpus = cfg.num_gpus.max(1);
        let num_nodes = (num_gpus / cfg.gpus_per_node.max(1)).max(1);
        emit(cfg.gpu_mtbf_s, 0x6770_7573, &mut |s| {
            FaultTarget::Gpu((splitmix64(s) % num_gpus as u64) as usize)
        });
        emit(cfg.node_mtbf_s, 0x6e6f_6465, &mut |s| {
            FaultTarget::Node((splitmix64(s) % num_nodes as u64) as usize)
        });
        emit(cfg.link_mtbf_s, 0x6c69_6e6b, &mut |_| FaultTarget::NvSwitch);
        FaultTrace::new(events, cfg.gpus_per_node)
    }

    /// Parse a declarative trace file: one event per line,
    /// `"<t_s> fail|repair gpu <i>|node <i>|nvswitch|ib"`; blank lines and
    /// `#` comments are skipped.
    pub fn parse(text: &str, gpus_per_node: usize) -> Result<Self> {
        let mut events = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let err = || format!("fault trace line {}: {raw:?}", lineno + 1);
            let t_s: f64 = it
                .next()
                .with_context(err)?
                .parse()
                .with_context(err)?;
            let kind = match it.next().with_context(err)? {
                "fail" => FaultKind::Fail,
                "repair" => FaultKind::Repair,
                other => bail!("unknown fault kind {other:?} ({})", err()),
            };
            let target = match it.next().with_context(err)? {
                "gpu" => FaultTarget::Gpu(it.next().with_context(err)?.parse().with_context(err)?),
                "node" => {
                    FaultTarget::Node(it.next().with_context(err)?.parse().with_context(err)?)
                }
                "nvswitch" => FaultTarget::NvSwitch,
                "ib" => FaultTarget::Ib,
                other => bail!("unknown fault target {other:?} ({})", err()),
            };
            if it.next().is_some() {
                bail!("trailing tokens ({})", err());
            }
            events.push(FaultEvent { t_s, kind, target });
        }
        Ok(FaultTrace::new(events, gpus_per_node))
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Render back to the declarative line format (round-trips `parse`).
    pub fn to_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for ev in &self.events {
            let _ = writeln!(out, "{} {} {}", ev.t_s, ev.kind, ev.target);
        }
        out
    }
}

/// The scheduler's fault-tolerance configuration: the hardware event
/// schedule plus the checkpoint cadence.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub trace: FaultTrace,
    /// Virtual seconds between [`Workload::snapshot`]
    /// (crate::workload::Workload::snapshot) captures of every running
    /// tenant. The capture cost (one host-staged parameter dump per
    /// member) is charged to the tenant's own executors.
    /// `f64::INFINITY` disables checkpointing — a killed tenant then
    /// restarts from scratch.
    pub checkpoint_interval_s: f64,
}

impl FaultPlan {
    pub fn new(trace: FaultTrace) -> Self {
        FaultPlan { trace, checkpoint_interval_s: f64::INFINITY }
    }

    pub fn with_checkpoint_interval(mut self, interval_s: f64) -> Self {
        self.checkpoint_interval_s = interval_s;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;

    fn cfg(seed: u64) -> FaultTraceConfig {
        FaultTraceConfig {
            seed,
            duration_s: 10.0,
            num_gpus: 8,
            gpus_per_node: 2,
            gpu_mtbf_s: 2.0,
            node_mtbf_s: 6.0,
            link_mtbf_s: 8.0,
            repair_after_s: Some(1.0),
        }
    }

    #[test]
    fn generate_is_deterministic_and_sorted() {
        let a = FaultTrace::generate(&cfg(7));
        let b = FaultTrace::generate(&cfg(7));
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(a.events.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        assert!(a.events.iter().all(|e| e.t_s < 10.0));
        let c = FaultTrace::generate(&cfg(8));
        assert_ne!(a, c, "different seeds give different traces");
    }

    #[test]
    fn parse_round_trips() {
        let text = "0.25 fail gpu 3\n0.4 fail node 1\n0.6 repair gpu 3\n0.8 fail nvswitch\n";
        let t = FaultTrace::parse(text, 2).unwrap();
        assert_eq!(t.events.len(), 4);
        assert_eq!(t.events[1].target, FaultTarget::Node(1));
        let again = FaultTrace::parse(&t.to_text(), 2).unwrap();
        assert_eq!(t, again);
        // comments + blank lines + sorting
        let t2 = FaultTrace::parse("# hi\n\n0.5 fail gpu 1 # inline\n0.1 fail ib\n", 1).unwrap();
        assert_eq!(t2.events[0].target, FaultTarget::Ib);
        // malformed lines error
        assert!(FaultTrace::parse("0.5 explode gpu 1", 1).is_err());
        assert!(FaultTrace::parse("0.5 fail gpu", 1).is_err());
        assert!(FaultTrace::parse("x fail gpu 1", 1).is_err());
    }

    #[test]
    fn apply_marks_and_repairs_fabric() {
        let mut f = Fabric::single_node(Topology::flat_cluster(2, 2));
        let ev = |t_s, kind, target| FaultEvent { t_s, kind, target };
        ev(0.0, FaultKind::Fail, FaultTarget::Node(1)).apply(&mut f, 2);
        assert!(f.gpu_failed(2) && f.gpu_failed(3) && !f.gpu_failed(0));
        assert_eq!(f.failed_gpu_list(), vec![2, 3]);
        // a dead GPU's host path is out of service
        assert!(f.link_failed(f.host_link(2)));
        ev(0.0, FaultKind::Fail, FaultTarget::NvSwitch).apply(&mut f, 2);
        assert!(f.link_failed(f.nvswitch_link()));
        ev(1.0, FaultKind::Repair, FaultTarget::Node(1)).apply(&mut f, 2);
        ev(1.0, FaultKind::Repair, FaultTarget::NvSwitch).apply(&mut f, 2);
        assert!(!f.has_failures());
        // ib on a single-node fabric is a no-op
        ev(2.0, FaultKind::Fail, FaultTarget::Ib).apply(&mut f, 2);
        assert!(!f.has_failures());
    }

    #[test]
    fn degraded_planner_reroutes_then_partitions() {
        let mut f = Fabric::single_node(Topology::dgx_a100(4));
        let mpl: Vec<Vec<usize>> = (0..4).map(|g| vec![2 * g, 2 * g + 1]).collect();
        let bytes = 6 << 20;
        let (healthy, _) = f.try_cheapest_allreduce(&mpl, bytes).unwrap();
        f.fail_link(f.nvswitch_link());
        let (degraded, plan) = f.try_cheapest_allreduce(&mpl, bytes).unwrap();
        assert_ne!(healthy, degraded, "NVSwitch death must force a different strategy");
        assert!(f.plan_valid(&plan));
        // killing every host path too partitions the group
        for g in 0..4 {
            f.fail_gpu(g);
        }
        assert!(f.try_cheapest_allreduce(&mpl, bytes).is_err());
    }
}
