"""Environment tests: Table 6 registry, dynamics, rewards, resets."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.envs import all_specs, get, init_state, split_state, step

TABLE6 = {
    "AT": ("Ant", "L", 60, 8, (256, 128, 64)),
    "AY": ("Anymal", "L", 48, 12, (256, 128, 64)),
    "BB": ("BallBalance", "L", 24, 3, (256, 128, 64)),
    "FC": ("FrankaCabinet", "F", 23, 9, (256, 128, 64)),
    "HM": ("Humanoid", "L", 108, 21, (200, 400, 100)),
    "SH": ("ShadowHand", "R", 211, 20, (512, 512, 512, 256)),
}


def test_registry_matches_table6():
    specs = all_specs()
    assert set(specs) == set(TABLE6)
    for abbr, (name, kind, obs, act, hidden) in TABLE6.items():
        s = specs[abbr]
        assert s.name == name and s.kind == kind
        assert s.obs_dim == obs and s.act_dim == act
        assert tuple(s.hidden) == hidden


@pytest.mark.parametrize("abbr", list(TABLE6))
def test_step_shapes_and_finiteness(abbr):
    spec = get(abbr)
    n = 32
    key = jax.random.PRNGKey(0)
    s = init_state(spec, n, key)
    assert s.shape == (n, spec.obs_dim)
    a = 0.1 * jax.random.normal(key, (n, spec.act_dim))
    s2, r, d = step(spec, s, a)
    assert s2.shape == s.shape
    assert r.shape == (n,)
    assert d.shape == (n,)
    assert np.all(np.isfinite(np.asarray(s2)))
    assert np.all(np.isfinite(np.asarray(r)))
    assert set(np.unique(np.asarray(d))) <= {0.0, 1.0}


def test_step_deterministic():
    spec = get("AT")
    key = jax.random.PRNGKey(1)
    s = init_state(spec, 8, key)
    a = jnp.ones((8, spec.act_dim)) * 0.3
    s1, r1, _ = step(spec, s, a)
    s2, r2, _ = step(spec, s, a)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_dynamics_respond_to_actions():
    """Actions must actually move the system (the policy has leverage)."""
    spec = get("AT")
    key = jax.random.PRNGKey(2)
    s = init_state(spec, 8, key)
    zero = jnp.zeros((8, spec.act_dim))
    one = jnp.ones((8, spec.act_dim))
    s_zero, _, _ = step(spec, s, zero)
    s_one, _, _ = step(spec, s, one)
    assert not np.allclose(np.asarray(s_zero), np.asarray(s_one))


def test_control_cost_penalizes_large_actions():
    spec = get("AT")
    key = jax.random.PRNGKey(3)
    s = init_state(spec, 64, key)
    # same state, velocities zeroed -> reward difference is control cost +
    # action-induced velocity; with clipped huge actions the ctrl term grows.
    small = 0.01 * jnp.ones((64, spec.act_dim))
    # actions are clipped to [-1,1]; compare |a|=0.01 vs |a|=1
    big = jnp.ones((64, spec.act_dim))
    _, r_small, _ = step(spec, s, small)
    _, r_big, _ = step(spec, s, big)
    # not a strict inequality env-wise (velocity reward differs), but the
    # control penalty must show up in the mean for a zero-velocity start
    assert float(jnp.mean(r_big)) < float(jnp.mean(r_small)) + 1.0


def test_runaway_states_reset():
    spec = get("BB")
    n = 4
    key = jax.random.PRNGKey(4)
    s = init_state(spec, n, key)
    q, v, extra = split_state(spec, s)
    # blow up the coordinates past the reset limit
    q = q.at[:2].set(spec.reset_limit * 10.0)
    s_bad = jnp.concatenate([q, v, extra], axis=1)
    s2, _, d = step(spec, s_bad, jnp.zeros((n, spec.act_dim)))
    d = np.asarray(d)
    assert d[0] == 1.0 and d[1] == 1.0
    q2, _, _ = split_state(spec, s2)
    assert np.all(np.abs(np.asarray(q2)[:2]) < spec.reset_limit)


def test_velocity_increases_forward_reward():
    """Locomotion reward must reward forward velocity — the learning signal."""
    spec = get("AT")
    n = 8
    key = jax.random.PRNGKey(5)
    s = init_state(spec, n, key)
    q, v, extra = split_state(spec, s)
    v_fast = v.at[:, 0].set(2.0)
    s_fast = jnp.concatenate([q, v_fast, extra], axis=1)
    a = jnp.zeros((n, spec.act_dim))
    _, r_slow, _ = step(spec, s, a)
    _, r_fast, _ = step(spec, s_fast, a)
    assert float(jnp.mean(r_fast)) > float(jnp.mean(r_slow))


@pytest.mark.parametrize("abbr,reward", [("FC", "reach"), ("SH", "orient")])
def test_task_reward_styles(abbr, reward):
    spec = get(abbr)
    assert spec.reward == reward
    key = jax.random.PRNGKey(6)
    s = init_state(spec, 16, key)
    _, r, _ = step(spec, s, jnp.zeros((16, spec.act_dim)))
    r = np.asarray(r)
    assert np.all(np.isfinite(r))
    if reward == "orient":
        # cosine-alignment reward is bounded
        assert np.all(r <= 1.2) and np.all(r >= -1.2)
