"""L2 model tests: parameter layout, GAE, PPO gradients, Adam, rollout."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.envs import all_specs, get


def test_param_counts_match_paper_table7():
    # Table 7: AT 1.1e5, HM 2.9e5, SH 1.5e6 parameters.
    specs = all_specs()
    assert abs(model.num_params(specs["AT"]) - 1.1e5) / 1.1e5 < 0.1
    assert abs(model.num_params(specs["HM"]) - 2.9e5) / 2.9e5 < 0.05
    assert abs(model.num_params(specs["SH"]) - 1.5e6) / 1.5e6 < 0.05


def test_flatten_unflatten_roundtrip():
    spec = get("BB")
    key = jax.random.PRNGKey(0)
    flat = model.init_params(spec, key)
    assert flat.shape == (model.num_params(spec),)
    tree = model.unflatten(spec, flat)
    flat2 = model.flatten_tree(spec, tree)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(flat2))
    # layout covers every parameter exactly once
    total = sum(np.prod(s) for _, s in model.param_layout(spec))
    assert total == flat.size


def test_policy_forward_shapes():
    spec = get("AT")
    key = jax.random.PRNGKey(1)
    params = model.init_params(spec, key)
    obs = jax.random.normal(key, (17, spec.obs_dim))
    mean, value, log_std = model.policy_forward(spec, params, obs)
    assert mean.shape == (17, spec.act_dim)
    assert value.shape == (17,)
    assert log_std.shape == (spec.act_dim,)
    assert np.all(np.isfinite(np.asarray(mean)))


def test_gae_against_naive_loop():
    m, n = 5, 3
    key = jax.random.PRNGKey(2)
    rewards = jax.random.normal(key, (m, n))
    values = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    dones = (jax.random.uniform(jax.random.fold_in(key, 2), (m, n)) < 0.2).astype(jnp.float32)
    last_value = jax.random.normal(jax.random.fold_in(key, 3), (n,))
    advs, rets = model.gae(rewards, values, dones, last_value)

    # naive reference
    g, lam = model.GAMMA, model.LAM
    adv_ref = np.zeros((m, n), dtype=np.float64)
    r = np.asarray(rewards)
    v = np.asarray(values)
    d = np.asarray(dones)
    lv = np.asarray(last_value)
    running = np.zeros(n)
    for t in reversed(range(m)):
        v_next = lv if t == m - 1 else v[t + 1]
        nonterm = 1.0 - d[t]
        delta = r[t] + g * v_next * nonterm - v[t]
        running = delta + g * lam * nonterm * running
        adv_ref[t] = running
    np.testing.assert_allclose(np.asarray(advs), adv_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rets), adv_ref + v, rtol=1e-5, atol=1e-5)


def test_rollout_and_grad_pipeline():
    spec = get("BB")
    n, m = 16, 4
    key = jax.random.PRNGKey(3)
    init = model.build_init(spec, n)
    params, state0 = init(0)
    assert params.shape == (model.num_params(spec),)
    assert state0.shape == (n, spec.obs_dim)

    rollout = jax.jit(model.build_rollout(spec, n, m))
    obs, acts, logps, rews, vals, dones, last_state, last_value = rollout(params, state0, 1)
    assert obs.shape == (m, n, spec.obs_dim)
    assert acts.shape == (m, n, spec.act_dim)
    for x in (logps, rews, vals, dones):
        assert x.shape == (m, n)
    assert last_value.shape == (n,)

    grad_fn = jax.jit(model.build_grad(spec, n, m))
    grads, loss, pi_l, v_l, ent, kl, mean_r = grad_fn(
        params, obs, acts, logps, rews, vals, dones, last_value
    )
    assert grads.shape == params.shape
    gnorm = float(jnp.linalg.norm(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    assert np.isfinite(float(loss))
    # fresh rollout: ratio=1 -> pi loss ~ -mean(adv_norm * 1) ~ 0, kl ~ 0
    assert abs(float(kl)) < 1e-2


def test_grad_descends_loss():
    """A few SGD steps along the PPO gradient must reduce the loss on the
    same batch — the core learning signal."""
    spec = get("BB")
    n, m = 32, 4
    init = model.build_init(spec, n)
    params, state0 = init(0)
    rollout = jax.jit(model.build_rollout(spec, n, m))
    obs, acts, logps, rews, vals, dones, _last_state, last_value = rollout(params, state0, 1)
    grad_fn = jax.jit(model.build_grad(spec, n, m))

    p = params
    losses = []
    for _ in range(5):
        out = grad_fn(p, obs, acts, logps, rews, vals, dones, last_value)
        losses.append(float(out[1]))
        p = p - 1e-3 * out[0]
    assert losses[-1] < losses[0], losses


def test_adam_apply_matches_reference():
    spec = get("BB")
    P = model.num_params(spec)
    key = jax.random.PRNGKey(4)
    params = jax.random.normal(key, (P,)) * 0.1
    grads = jax.random.normal(jax.random.fold_in(key, 1), (P,)) * 0.01
    m0 = jnp.zeros(P)
    v0 = jnp.zeros(P)
    apply_fn = jax.jit(model.build_apply(spec))
    p1, m1, v1, t1 = apply_fn(params, m0, v0, jnp.int32(0), grads, jnp.float32(1e-3))
    assert int(t1) == 1

    # reference Adam step 1
    b1, b2, eps = model.ADAM_B1, model.ADAM_B2, model.ADAM_EPS
    m_ref = (1 - b1) * np.asarray(grads)
    v_ref = (1 - b2) * np.asarray(grads) ** 2
    mhat = m_ref / (1 - b1)
    vhat = v_ref / (1 - b2)
    p_ref = np.asarray(params) - 1e-3 * mhat / (np.sqrt(vhat) + eps)
    np.testing.assert_allclose(np.asarray(p1), p_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), m_ref, rtol=1e-5, atol=1e-8)


def test_rollout_deterministic_in_seed():
    spec = get("BB")
    n, m = 8, 3
    init = model.build_init(spec, n)
    params, state0 = init(7)
    rollout = jax.jit(model.build_rollout(spec, n, m))
    a = rollout(params, state0, 5)
    b = rollout(params, state0, 5)
    c = rollout(params, state0, 6)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    assert not np.array_equal(np.asarray(a[1]), np.asarray(c[1]))


@pytest.mark.parametrize("abbr", ["AT", "HM"])
def test_policy_uses_pallas_kernel_layers(abbr):
    """The lowered rollout must contain the Pallas-kernel matmuls for every
    policy layer (actor + critic trunks + heads)."""
    spec = get(abbr)
    n, m = 4, 2
    rollout = model.build_rollout(spec, n, m)
    P = model.num_params(spec)
    lowered = jax.jit(rollout).lower(
        jax.ShapeDtypeStruct((P,), jnp.float32),
        jax.ShapeDtypeStruct((n, spec.obs_dim), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    text = lowered.as_text()
    assert "dot_general" in text  # the kernels' MXU matmuls survived lowering
