"""AOT driver tests: HLO text lowering + manifest formats."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("aot")
    r = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out",
            str(out),
            "--benchmarks",
            "BB",
            "--num-env",
            "16",
            "--horizon",
            "4",
        ],
        cwd=ROOT,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    return out


def test_all_artifacts_written(artifacts):
    for name in ["init", "rollout", "grad", "apply"]:
        p = artifacts / "BB" / f"{name}.hlo.txt"
        assert p.exists(), f"missing {p}"
        text = p.read_text()
        # HLO text format, entry computation present
        assert text.startswith("HloModule"), text[:60]
        assert "ENTRY" in text
        # tuple-rooted (return_tuple=True contract with the rust loader)
        assert "ROOT" in text


def test_manifest_json_and_txt_agree(artifacts):
    man = json.loads((artifacts / "manifest.json").read_text())
    bb = man["benchmarks"]["BB"]
    assert bb["obs_dim"] == 24 and bb["act_dim"] == 3
    assert bb["num_env"] == 16 and bb["horizon"] == 4
    txt = (artifacts / "manifest.txt").read_text()
    assert "bench BB" in txt
    assert f"num_params {bb['num_params']}" in txt
    assert "file rollout rollout.hlo.txt" in txt
    assert txt.strip().endswith("end")


def test_hlo_has_no_serialized_proto_markers(artifacts):
    """Guard against regressing to .serialize() (xla_extension 0.5.1 rejects
    jax>=0.5 64-bit-id protos; text is the contract)."""
    blob = (artifacts / "BB" / "rollout.hlo.txt").read_bytes()
    assert blob.isascii()


def test_rollout_entry_has_expected_parameters(artifacts):
    text = (artifacts / "BB" / "rollout.hlo.txt").read_text()
    entry = text[text.index("ENTRY") :]
    params = [l for l in entry.splitlines() if "parameter(" in l]
    # params_flat, state, seed
    assert len(params) == 3, params
    assert any("f32[16,24]" in l for l in params), params  # state (n, obs)
    assert any("s32[]" in l for l in params), params  # seed
