"""L1 correctness: Pallas fused kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal for the kernel layer: values AND
custom-vjp gradients must match the oracle, across a hypothesis sweep of
shapes (including non-multiples of the lane/block sizes, which exercise the
padding paths).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from compile.kernels.fused_mlp import (
    BLOCK_B,
    LANE,
    fused_linear,
    mlp_forward,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import (
    fused_linear_bwd_ref,
    fused_linear_ref,
    mlp_forward_ref,
)


def _rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def _mats(seed, b, din, dout):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    return _rand(k1, b, din), _rand(k2, din, dout) * 0.3, _rand(k3, dout) * 0.1, _rand(k4, b, dout)


@pytest.mark.parametrize("act", ["tanh", "none"])
@pytest.mark.parametrize(
    "b,din,dout",
    [(4, 8, 8), (7, 5, 3), (128, 60, 256), (130, 211, 512), (1, 1, 1), (256, 48, 12)],
)
def test_forward_matches_ref(act, b, din, dout):
    x, w, bias, _ = _mats(0, b, din, dout)
    got = fused_linear(x, w, bias, act)
    want = fused_linear_ref(x, w, bias, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ["tanh", "none"])
@pytest.mark.parametrize("b,din,dout", [(7, 5, 3), (64, 24, 16), (130, 60, 8)])
def test_backward_matches_handwritten_ref(act, b, din, dout):
    x, w, bias, g = _mats(1, b, din, dout)

    def f(x, w, bias):
        return jnp.sum(fused_linear(x, w, bias, act) * g)

    dx, dw, db = jax.grad(f, argnums=(0, 1, 2))(x, w, bias)
    rdx, rdw, rdb = fused_linear_bwd_ref(x, w, bias, g, act)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rdx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(rdw), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db), np.asarray(rdb), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act", ["tanh", "none"])
def test_backward_matches_autodiff_of_ref(act):
    x, w, bias, g = _mats(2, 33, 19, 11)

    def f_pallas(x, w, bias):
        return jnp.sum(fused_linear(x, w, bias, act) * g)

    def f_ref(x, w, bias):
        return jnp.sum(fused_linear_ref(x, w, bias, act) * g)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, bias)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, bias)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-4)


def test_mlp_forward_matches_ref():
    k = jax.random.PRNGKey(3)
    dims = [60, 256, 128, 64, 8]
    layers = []
    for din, dout in zip(dims[:-1], dims[1:]):
        k, k1, k2 = jax.random.split(k, 3)
        layers.append((_rand(k1, din, dout) * 0.2, _rand(k2, dout) * 0.05))
    x = _rand(k, 37, 60)
    got = mlp_forward(x, layers)
    want = mlp_forward_ref(x, layers)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_jit_consistency():
    """The kernel must produce identical results jitted and unjitted
    (the artifact path is always jitted)."""
    x, w, bias, _ = _mats(4, 50, 23, 9)
    eager = fused_linear(x, w, bias, "tanh")
    jitted = jax.jit(lambda *a: fused_linear(*a, "tanh"))(x, w, bias)
    np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted), rtol=1e-6, atol=1e-6)


def test_batch_block_boundary():
    """Batch sizes straddling BLOCK_B exercise grid + padding edge cases."""
    for b in [BLOCK_B - 1, BLOCK_B, BLOCK_B + 1, 2 * BLOCK_B + 3]:
        x, w, bias, _ = _mats(5, b, LANE + 1, LANE - 1)
        got = fused_linear(x, w, bias, "tanh")
        want = fused_linear_ref(x, w, bias, "tanh")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_vmem_footprint_within_budget():
    """Perf invariant (DESIGN.md §6): every Table 6 layer's forward block
    fits the 16 MB VMEM budget at the chosen BLOCK_B."""
    layers = [(60, 256), (256, 128), (128, 64), (211, 512), (512, 512), (512, 256), (108, 200), (200, 400)]
    for din, dout in layers:
        assert vmem_footprint_bytes(din, dout) < 16 * 2**20


def test_mxu_utilization_reasonable():
    assert mxu_utilization_estimate(512, 512) == 1.0
    assert 0.0 < mxu_utilization_estimate(60, 8) <= 1.0


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(min_value=1, max_value=200),
        din=st.integers(min_value=1, max_value=96),
        dout=st.integers(min_value=1, max_value=96),
        act=st.sampled_from(["tanh", "none"]),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_hypothesis_shape_sweep(b, din, dout, act, seed):
        x, w, bias, _ = _mats(seed, b, din, dout)
        got = fused_linear(x, w, bias, act)
        want = fused_linear_ref(x, w, bias, act)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(min_value=1, max_value=64),
        din=st.integers(min_value=1, max_value=48),
        dout=st.integers(min_value=1, max_value=48),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_hypothesis_grad_sweep(b, din, dout, seed):
        x, w, bias, g = _mats(seed, b, din, dout)

        def f(x, w, bias):
            return jnp.sum(fused_linear(x, w, bias, "tanh") * g)

        dx, dw, db = jax.grad(f, argnums=(0, 1, 2))(x, w, bias)
        rdx, rdw, rdb = fused_linear_bwd_ref(x, w, bias, g, "tanh")
        np.testing.assert_allclose(np.asarray(dx), np.asarray(rdx), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(rdw), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(db), np.asarray(rdb), rtol=1e-3, atol=1e-3)
