"""L2 — the DRL compute graphs: actor-critic policy, rollout, PPO update.

Everything here is lowered ONCE by aot.py to HLO text and executed from the
rust coordinator; python never runs on the request path.

Four artifacts per benchmark (all take/return a FLAT f32 parameter vector so
the rust side moves exactly one buffer per direction — and so the LGR
gradient-reduction strategies in rust operate on a single contiguous
gradient vector, as the paper's §4.1 assumes):

  init(seed)                          -> (params_flat, state0)
  rollout(params_flat, state, seed)   -> (obs, actions, logps, rewards,
                                          values, dones, last_state, last_value)
  grad(params_flat, obs, actions, logps_old, rewards, values_old, dones,
       last_value)                    -> (grads_flat, loss, pi_loss, v_loss,
                                          entropy, approx_kl, mean_reward)
  apply(params_flat, m, v, step, grads_flat, lr)
                                      -> (params', m', v', step')

The policy is the paper's Table 6 architecture: *separate* actor and critic
MLPs with identical trunks (this matches the paper's reported parameter
counts: AT 1.1e5, HM 2.9e5, SH 1.5e6) plus a state-independent log-std
vector. Every MLP layer runs through the L1 Pallas fused kernel.
"""

from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .envs.base import EnvSpec, init_state, step
from .kernels.fused_mlp import mlp_forward

# PPO hyperparameters (fixed into the artifacts). Gamma/lambda are tuned to
# the 16-step rollout window of the artifacts (credit assignment must fit
# the horizon); entropy weight is kept small so the exploration-noise
# control cost doesn't swamp the locomotion signal.
GAMMA = 0.95
LAM = 0.9
CLIP = 0.2
VCOEF = 1.0
ENTCOEF = 0.001
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
LOGSTD_INIT = -1.0


# ---------------------------------------------------------------------------
# Parameter layout: flat vector <-> structured actor/critic layers.
# ---------------------------------------------------------------------------


def layer_dims(spec: EnvSpec) -> List[Tuple[int, int]]:
    dims = [spec.obs_dim, *spec.hidden]
    return list(zip(dims[:-1], dims[1:]))


def param_layout(spec: EnvSpec):
    """Returns [(name, shape), ...] in flat-vector order."""
    layout = []
    trunk = layer_dims(spec)
    for i, (din, dout) in enumerate(trunk):
        layout.append((f"actor.w{i}", (din, dout)))
        layout.append((f"actor.b{i}", (dout,)))
    layout.append(("actor.head.w", (spec.hidden[-1], spec.act_dim)))
    layout.append(("actor.head.b", (spec.act_dim,)))
    for i, (din, dout) in enumerate(trunk):
        layout.append((f"critic.w{i}", (din, dout)))
        layout.append((f"critic.b{i}", (dout,)))
    layout.append(("critic.head.w", (spec.hidden[-1], 1)))
    layout.append(("critic.head.b", (1,)))
    layout.append(("log_std", (spec.act_dim,)))
    return layout


def num_params(spec: EnvSpec) -> int:
    return sum(math.prod(s) for _, s in param_layout(spec))


def unflatten(spec: EnvSpec, flat: jnp.ndarray):
    """Flat f32[P] -> dict of named arrays (pure reshape/slice; XLA fuses)."""
    out = {}
    ofs = 0
    for name, shape in param_layout(spec):
        n = math.prod(shape)
        out[name] = flat[ofs : ofs + n].reshape(shape)
        ofs += n
    return out


def flatten_tree(spec: EnvSpec, tree) -> jnp.ndarray:
    return jnp.concatenate([tree[name].ravel() for name, _ in param_layout(spec)])


def init_params(spec: EnvSpec, key) -> jnp.ndarray:
    """Orthogonal-ish (scaled normal) init, flat vector."""
    parts = []
    for name, shape in param_layout(spec):
        key, sub = jax.random.split(key)
        if name == "log_std":
            parts.append(jnp.full(shape, LOGSTD_INIT, dtype=jnp.float32).ravel())
        elif name.endswith("head.w"):
            fan_in = shape[0]
            w = jax.random.normal(sub, shape, dtype=jnp.float32) * (0.01 / math.sqrt(fan_in))
            parts.append(w.ravel())
        elif ".w" in name:
            fan_in = shape[0]
            w = jax.random.normal(sub, shape, dtype=jnp.float32) * math.sqrt(2.0 / fan_in)
            parts.append(w.ravel())
        else:
            parts.append(jnp.zeros(shape, dtype=jnp.float32).ravel())
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# Policy forward (actor + critic), all layers through the Pallas kernel.
# ---------------------------------------------------------------------------


def _mlp_layers(p, prefix: str, n_trunk: int):
    layers = [(p[f"{prefix}.w{i}"], p[f"{prefix}.b{i}"]) for i in range(n_trunk)]
    layers.append((p[f"{prefix}.head.w"], p[f"{prefix}.head.b"]))
    return layers


def policy_forward(spec: EnvSpec, params_flat: jnp.ndarray, obs: jnp.ndarray):
    """Returns (action_mean [n,A], value [n], log_std [A])."""
    p = unflatten(spec, params_flat)
    n_trunk = len(spec.hidden)
    mean = mlp_forward(obs, _mlp_layers(p, "actor", n_trunk))
    value = mlp_forward(obs, _mlp_layers(p, "critic", n_trunk))[:, 0]
    return mean, value, p["log_std"]


def _gauss_logp(mean, log_std, act):
    var = jnp.exp(2.0 * log_std)
    return jnp.sum(
        -0.5 * ((act - mean) ** 2) / var - log_std - 0.5 * math.log(2.0 * math.pi),
        axis=-1,
    )


# ---------------------------------------------------------------------------
# Artifact bodies.
# ---------------------------------------------------------------------------


def build_init(spec: EnvSpec, num_env: int):
    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        kp, ks = jax.random.split(key)
        params = init_params(spec, kp)
        state0 = init_state(spec, num_env, ks)
        return (params, state0)

    return init_fn


def build_rollout(spec: EnvSpec, num_env: int, horizon: int):
    """`horizon` interaction steps fused into one artifact via lax.scan —
    this is the serving/experience-collection hot path (the paper's
    Simulator+Agent co-located in one GMI; intra-GMI sharing is free)."""

    def rollout_fn(params_flat, state, seed):
        key = jax.random.PRNGKey(seed)

        def body(carry, k):
            st = carry
            obs = st  # fully-observed: observation == state vector
            mean, value, log_std = policy_forward(spec, params_flat, obs)
            noise = jax.random.normal(k, mean.shape, dtype=jnp.float32)
            act = mean + jnp.exp(log_std)[None, :] * noise
            logp = _gauss_logp(mean, log_std[None, :], act)
            st2, reward, done = step(spec, st, act)
            return st2, (obs, act, logp, reward, value, done)

        keys = jax.random.split(key, horizon)
        last_state, (obs, acts, logps, rews, vals, dones) = jax.lax.scan(body, state, keys)
        _, last_value, _ = policy_forward(spec, params_flat, last_state)
        return (obs, acts, logps, rews, vals, dones, last_state, last_value)

    return rollout_fn


def gae(rewards, values, dones, last_value):
    """Generalized advantage estimation over the scanned horizon."""

    def body(carry, xs):
        adv_next, v_next = carry
        r, v, d = xs
        nonterm = 1.0 - d
        delta = r + GAMMA * v_next * nonterm - v
        adv = delta + GAMMA * LAM * nonterm * adv_next
        return (adv, v), adv

    (_, _), advs = jax.lax.scan(
        body,
        (jnp.zeros_like(last_value), last_value),
        (rewards, values, dones),
        reverse=True,
    )
    returns = advs + values
    return advs, returns


def build_grad(spec: EnvSpec, num_env: int, horizon: int):
    """PPO clipped-surrogate gradient over the full collected batch.

    Outputs a FLAT gradient vector: the rust LGR layer (MPR/MRR/HAR)
    allreduces it across trainer GMIs, then `apply` consumes the reduced
    vector. This is exactly the decomposition the paper's §4.1 optimizes.
    """

    def grad_fn(params_flat, obs, acts, logps_old, rewards, values_old, dones, last_value):
        advs, returns = gae(rewards, values_old, dones, last_value)
        advs = (advs - jnp.mean(advs)) / (jnp.std(advs) + 1e-8)

        obs_f = obs.reshape(horizon * num_env, spec.obs_dim)
        acts_f = acts.reshape(horizon * num_env, spec.act_dim)
        logp_f = logps_old.reshape(-1)
        adv_f = advs.reshape(-1)
        ret_f = returns.reshape(-1)

        def loss_fn(pf):
            mean, value, log_std = policy_forward(spec, pf, obs_f)
            logp = _gauss_logp(mean, log_std[None, :], acts_f)
            ratio = jnp.exp(logp - logp_f)
            surr = jnp.minimum(
                ratio * adv_f, jnp.clip(ratio, 1.0 - CLIP, 1.0 + CLIP) * adv_f
            )
            pi_loss = -jnp.mean(surr)
            v_loss = 0.5 * jnp.mean((value - ret_f) ** 2)
            ent = jnp.sum(log_std + 0.5 * math.log(2.0 * math.pi * math.e))
            loss = pi_loss + VCOEF * v_loss - ENTCOEF * ent
            kl = jnp.mean(logp_f - logp)
            return loss, (pi_loss, v_loss, ent, kl)

        (loss, (pi_loss, v_loss, ent, kl)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params_flat)
        return (grads, loss, pi_loss, v_loss, ent, kl, jnp.mean(rewards))

    return grad_fn


def build_apply(spec: EnvSpec):
    """Adam step on the flat vectors (buffers donated by the rust runtime —
    the update loop is allocation-free after warmup)."""

    def apply_fn(params_flat, m, v, step_i, grads_flat, lr):
        t = step_i + 1
        m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * grads_flat
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * grads_flat * grads_flat
        tf = t.astype(jnp.float32)
        mhat = m2 / (1.0 - ADAM_B1**tf)
        vhat = v2 / (1.0 - ADAM_B2**tf)
        new_params = params_flat - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        return (new_params, m2, v2, t)

    return apply_fn
