"""AOT driver: lower every benchmark's compute graphs to HLO text.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly.

Outputs, per benchmark abbr (e.g. artifacts/AT/):
    init.hlo.txt  rollout.hlo.txt  grad.hlo.txt  apply.hlo.txt
plus a global artifacts/manifest.json the rust runtime reads to know shapes.

Run via `make artifacts` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .envs import all_specs

# Default shapes baked into the artifacts. Throughput *accounting* in rust
# uses the virtual-timeline work model (DESIGN.md §5), so the artifact batch
# only needs to be large enough for real numerics, not paper-scale.
DEFAULT_NUM_ENV = 256
DEFAULT_HORIZON = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32():
    return jax.ShapeDtypeStruct((), jnp.int32)


def lower_benchmark(spec, num_env: int, horizon: int, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    P = model.num_params(spec)
    D, A, m, n = spec.obs_dim, spec.act_dim, horizon, num_env

    arts = {
        "init": (model.build_init(spec, n), [i32()]),
        "rollout": (
            model.build_rollout(spec, n, m),
            [f32(P), f32(n, D), i32()],
        ),
        "grad": (
            model.build_grad(spec, n, m),
            [f32(P), f32(m, n, D), f32(m, n, A), f32(m, n), f32(m, n), f32(m, n), f32(m, n), f32(n)],
        ),
        "apply": (
            model.build_apply(spec),
            [f32(P), f32(P), f32(P), i32(), f32(P), jax.ShapeDtypeStruct((), jnp.float32)],
        ),
    }
    files = {}
    for name, (fn, in_specs) in arts.items():
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        files[name] = os.path.basename(path)
        print(f"  {spec.abbr}/{name}: {len(text)} chars")

    return {
        "name": spec.name,
        "abbr": spec.abbr,
        "kind": spec.kind,
        "obs_dim": D,
        "act_dim": A,
        "hidden": list(spec.hidden),
        "num_params": P,
        "num_env": n,
        "horizon": m,
        "files": files,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--benchmarks", default="", help="comma-separated abbrs (default: all)")
    ap.add_argument("--num-env", type=int, default=DEFAULT_NUM_ENV)
    ap.add_argument("--horizon", type=int, default=DEFAULT_HORIZON)
    args = ap.parse_args()

    specs = all_specs()
    wanted = [s.strip() for s in args.benchmarks.split(",") if s.strip()] or list(specs)
    out_root = args.out

    manifest = {"version": 1, "benchmarks": {}}
    # Merge into an existing manifest so partial rebuilds keep other entries.
    man_path = os.path.join(out_root, "manifest.json")
    if os.path.exists(man_path):
        try:
            with open(man_path) as f:
                manifest = json.load(f)
        except Exception:
            pass

    for abbr in wanted:
        spec = specs[abbr]
        print(f"lowering {abbr} ({spec.name}) num_env={args.num_env} horizon={args.horizon}")
        entry = lower_benchmark(spec, args.num_env, args.horizon, os.path.join(out_root, abbr))
        manifest["benchmarks"][abbr] = entry

    os.makedirs(out_root, exist_ok=True)
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)

    # Plain-text twin of the manifest for the rust side (the offline build
    # environment has no JSON crate; this line-based format needs none).
    txt_path = os.path.join(out_root, "manifest.txt")
    with open(txt_path, "w") as f:
        f.write("version 1\n")
        for abbr, e in sorted(manifest["benchmarks"].items()):
            f.write(f"bench {abbr}\n")
            f.write(f"name {e['name']}\n")
            f.write(f"kind {e['kind']}\n")
            f.write(f"obs_dim {e['obs_dim']}\n")
            f.write(f"act_dim {e['act_dim']}\n")
            f.write("hidden " + ",".join(str(h) for h in e["hidden"]) + "\n")
            f.write(f"num_params {e['num_params']}\n")
            f.write(f"num_env {e['num_env']}\n")
            f.write(f"horizon {e['horizon']}\n")
            for k, v in sorted(e["files"].items()):
                f.write(f"file {k} {v}\n")
            f.write("end\n")
    print(f"wrote {man_path} and {txt_path}")


if __name__ == "__main__":
    main()
