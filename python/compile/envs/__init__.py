"""Benchmark environment registry — the six DRL benchmarks of Table 6."""

from . import ant, anymal, ballbalance, franka, humanoid, shadowhand  # noqa: F401
from .base import EnvSpec, all_specs, get, init_state, split_state, step  # noqa: F401
