"""Ant (AT) — locomotion, Table 6 row 1: obs 60, act 8, policy 60:256:128:64:8."""

from .base import EnvSpec, register

SPEC = register(
    EnvSpec(
        name="Ant",
        abbr="AT",
        kind="L",
        obs_dim=60,
        act_dim=8,
        hidden=(256, 128, 64),
        dt=0.05,
        damping=0.25,
        stiffness=0.6,
        act_gain=1.2,
        reward="forward",
    )
)
