"""FrankaCabinet (FC) — operational-space manipulation [Khatib 1987],
Table 6: obs 23, act 9, policy 23:256:128:64:9. Reward: reach the cabinet
handle pose stored in the task extras."""

from .base import EnvSpec, register

SPEC = register(
    EnvSpec(
        name="FrankaCabinet",
        abbr="FC",
        kind="F",
        obs_dim=23,
        act_dim=9,
        hidden=(256, 128, 64),
        dt=0.03,
        damping=0.12,
        stiffness=0.9,
        act_gain=1.0,
        reward="reach",
    )
)
