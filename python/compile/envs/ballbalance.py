"""BallBalance (BB) — balance task, Table 6: obs 24, act 3, policy 24:256:128:64:3."""

from .base import EnvSpec, register

SPEC = register(
    EnvSpec(
        name="BallBalance",
        abbr="BB",
        kind="L",
        obs_dim=24,
        act_dim=3,
        hidden=(256, 128, 64),
        dt=0.02,
        damping=0.2,
        stiffness=1.2,
        act_gain=0.8,
        reward="forward",
    )
)
