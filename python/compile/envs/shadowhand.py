"""ShadowHand (SH) — dexterous in-hand reorientation [Andrychowicz 2020],
Table 6: obs 211, act 20, policy 211:512:512:512:256:20. Reward: align the
object pose coordinates with the target orientation in the task extras."""

from .base import EnvSpec, register

SPEC = register(
    EnvSpec(
        name="ShadowHand",
        abbr="SH",
        kind="R",
        obs_dim=211,
        act_dim=20,
        hidden=(512, 512, 512, 256),
        dt=0.02,
        damping=0.15,
        stiffness=1.0,
        act_gain=0.7,
        reward="orient",
    )
)
