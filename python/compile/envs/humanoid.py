"""Humanoid (HM) — bipedal locomotion, Table 6: obs 108, act 21,
policy 108:200:400:100:21 (note the paper's non-monotone hidden widths)."""

from .base import EnvSpec, register

SPEC = register(
    EnvSpec(
        name="Humanoid",
        abbr="HM",
        kind="L",
        obs_dim=108,
        act_dim=21,
        hidden=(200, 400, 100),
        dt=0.04,
        damping=0.25,
        stiffness=0.5,
        act_gain=1.5,
        reward="forward",
    )
)
