"""Anymal (AY) — quadruped locomotion, Table 6: obs 48, act 12, policy 48:256:128:64:12."""

from .base import EnvSpec, register

SPEC = register(
    EnvSpec(
        name="Anymal",
        abbr="AY",
        kind="L",
        obs_dim=48,
        act_dim=12,
        hidden=(256, 128, 64),
        dt=0.04,
        damping=0.25,
        stiffness=0.8,
        act_gain=1.0,
        reward="forward",
    )
)
