"""L2 — batched JAX physics environments (the Isaac Gym substitute).

The paper's simulation substrate is NVIDIA Isaac Gym (PhysX on GPU). That is
hardware- and license-gated here, so we build the closest synthetic
equivalent (DESIGN.md §1): a family of vectorized second-order rigid-body
systems with the paper's exact observation/action dimensions (Table 6).

Each environment simulates ``num_env`` independent systems. The state
vector of one system is ``[q (nq dims) | v (nq dims) | extras]`` where q are
generalized coordinates, v their velocities, and extras are task features
(targets, phase). The dynamics are a damped, coupled spring network driven
through a fixed actuation matrix — element-wise and gather/scatter-free but
deliberately *not* GEMM-shaped, so the compute signature matches the paper's
observation that env simulation underutilizes GEMM-oriented accelerators
(Fig 1b).

Rewards are task progress minus control cost, and policies trained with PPO
on these environments produce genuinely improving reward curves (Fig 9 /
examples/train_sync_e2e.rs).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """Static description of one benchmark environment (Table 6)."""

    name: str  # full benchmark name, e.g. "Ant"
    abbr: str  # paper abbreviation, e.g. "AT"
    kind: str  # "L" locomotion | "F" franka | "R" robotic hand
    obs_dim: int  # paper "#Dim."
    act_dim: int
    hidden: tuple  # policy hidden dims, e.g. (256, 128, 64)
    dt: float = 0.05
    # Velocity relaxation ~4 steps: actions must show up in the reward well
    # inside one PPO rollout window (horizon 16) for credit assignment.
    damping: float = 0.25
    stiffness: float = 0.6
    act_gain: float = 1.0
    ctrl_cost: float = 0.005
    reset_limit: float = 12.0
    # reward style: "forward" (locomotion), "reach" (franka), "orient" (hand)
    reward: str = "forward"

    @property
    def nq(self) -> int:
        """Number of generalized coordinates (state is [q | v | extras])."""
        return self.obs_dim // 2

    @property
    def n_extra(self) -> int:
        return self.obs_dim - 2 * self.nq


def _mix_matrix(spec: EnvSpec) -> jnp.ndarray:
    """Deterministic actuation matrix (act_dim -> nq): a fixed pseudo-random
    projection derived from iota hashing so it is a compile-time constant
    inside the lowered HLO (no weights file needed at runtime)."""
    a = jnp.arange(spec.act_dim, dtype=jnp.float32)[:, None]
    q = jnp.arange(spec.nq, dtype=jnp.float32)[None, :]
    m = jnp.sin(a * 12.9898 + q * 78.233 + 1.0) * 0.5
    # Normalize columns so the actuation scale is dim-independent.
    return spec.act_gain * m / jnp.sqrt(float(spec.act_dim))


def _coupling_matrix(spec: EnvSpec) -> jnp.ndarray:
    """Banded spring coupling between adjacent coordinates (tri-diagonal),
    the 'articulation' of the body. Kept banded, not dense: element-wise
    adds rather than a GEMM, matching the physics-sim compute signature."""
    return spec.stiffness


def init_state(spec: EnvSpec, num_env: int, key) -> jnp.ndarray:
    """Initial state: small random q, zero v, task extras."""
    kq, ke = jax.random.split(key)
    q = 0.1 * jax.random.normal(kq, (num_env, spec.nq), dtype=jnp.float32)
    v = jnp.zeros((num_env, spec.nq), dtype=jnp.float32)
    extra = jax.random.uniform(
        ke, (num_env, spec.n_extra), dtype=jnp.float32, minval=-1.0, maxval=1.0
    )
    return jnp.concatenate([q, v, extra], axis=1)


def split_state(spec: EnvSpec, s: jnp.ndarray):
    nq = spec.nq
    return s[:, :nq], s[:, nq : 2 * nq], s[:, 2 * nq :]


def step(spec: EnvSpec, state: jnp.ndarray, action: jnp.ndarray):
    """One physics step for all envs. Returns (new_state, reward, done).

    Dynamics (semi-implicit Euler, damped coupled springs):
        f   = M a - k q + k_c (roll(q,1) + roll(q,-1) - 2 q)
        v'  = (1 - c) v + dt f
        q'  = q + dt v'
    """
    q, v, extra = split_state(spec, state)
    mix = _mix_matrix(spec)
    act = jnp.clip(action, -1.0, 1.0)
    force = act @ mix  # (n, nq)
    # Locomotion tasks: coordinate 0 is the free forward/root coordinate —
    # no restoring spring (otherwise forward progress is transient and the
    # velocity reward cannot be sustained). Posture coordinates keep their
    # springs.
    free0 = 1.0 if spec.reward == "forward" else 0.0
    mask = jnp.ones((spec.nq,), dtype=jnp.float32).at[0].set(1.0 - free0)
    spring = -spec.stiffness * q * mask[None, :]
    couple = 0.25 * spec.stiffness * (
        jnp.roll(q, 1, axis=1) + jnp.roll(q, -1, axis=1) - 2.0 * q
    ) * mask[None, :]
    v_new = (1.0 - spec.damping) * v + spec.dt * (force + spring + couple)
    q_new = q + spec.dt * v_new

    reward = _reward(spec, q_new, v_new, extra, act)

    # Termination: runaway posture coordinates, or the free coordinate
    # passing the track end -> reset that env to a deterministic jittered
    # initial state (resets inside the artifact keep rust stateless).
    bad = jnp.max(jnp.abs(q_new), axis=1) > spec.reset_limit
    done = bad.astype(jnp.float32)
    jitter = 0.05 * jnp.sin(q_new * 37.0 + 11.0)
    q_new = jnp.where(bad[:, None], jitter * 0.1, q_new)
    v_new = jnp.where(bad[:, None], jnp.zeros_like(v_new), v_new)

    state_new = jnp.concatenate([q_new, v_new, extra], axis=1)
    return state_new, reward, done


def _reward(spec: EnvSpec, q, v, extra, act):
    ctrl = spec.ctrl_cost * jnp.sum(act * act, axis=1)
    alive = 0.05
    if spec.reward == "forward":
        # Locomotion: forward velocity along the first coordinate, plus a
        # small upright bonus (keep later coordinates near zero). The 2x
        # weight keeps the learning signal above the exploration-noise
        # floor within PPO's 16-step credit window.
        fwd = 2.0 * v[:, 0]
        upright = -0.02 * jnp.mean(q[:, 1:] * q[:, 1:], axis=1)
        return fwd + upright + alive - ctrl
    if spec.reward == "reach":
        # Franka: drive the first n_extra coordinates to the target pose in
        # `extra` (cabinet handle); dense negative-distance shaping.
        k = min(spec.nq, max(spec.n_extra, 1))
        tgt = extra[:, :k] if spec.n_extra else jnp.zeros_like(q[:, :k])
        d = q[:, :k] - tgt
        return 1.0 - jnp.sqrt(jnp.sum(d * d, axis=1) + 1e-6) + alive - ctrl
    if spec.reward == "orient":
        # ShadowHand: match an object orientation encoded in extras; reward
        # the cosine alignment of the first coordinates with the target.
        k = min(spec.nq, max(spec.n_extra, 1))
        tgt = extra[:, :k] if spec.n_extra else jnp.ones_like(q[:, :k])
        num = jnp.sum(q[:, :k] * tgt, axis=1)
        den = jnp.sqrt(jnp.sum(q[:, :k] ** 2, axis=1) * jnp.sum(tgt * tgt, axis=1) + 1e-6)
        return num / den + alive - ctrl
    raise ValueError(f"unknown reward style {spec.reward}")


_REGISTRY: Dict[str, EnvSpec] = {}


def register(spec: EnvSpec) -> EnvSpec:
    _REGISTRY[spec.abbr] = spec
    return spec


def get(abbr: str) -> EnvSpec:
    return _REGISTRY[abbr]


def all_specs() -> Dict[str, EnvSpec]:
    return dict(_REGISTRY)
