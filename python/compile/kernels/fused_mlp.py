"""L1 — Pallas fused MLP-layer kernels (forward + backward).

The compute hot-spot of GMI-DRL is the policy network: every
agent-environment interaction runs an actor MLP forward, and every PPO
update runs actor+critic forward/backward. We implement the fused
``y = act(x @ W + b)`` layer as a Pallas kernel pair (forward and backward)
wired together with ``jax.custom_vjp`` so the whole policy is
differentiable while both directions run through Pallas.

TPU adaptation (see DESIGN.md §2): the batch (num_env) dimension is the
parallel grid axis, blocked so each grid step's operands fit VMEM; the
feature dims are padded to a lane multiple so the inner matmul is
MXU-shaped. ``interpret=True`` always — the CPU PJRT plugin cannot run
Mosaic custom-calls; interpret-mode lowers the kernel to plain HLO so the
same artifact runs on the rust CPU client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane multiple for feature-dim padding. 8 keeps CPU-interpret tests cheap;
# on a real TPU this would be 128 (MXU systolic width) — the padding logic
# is identical, only the constant changes.
LANE = 8
# Batch block: rows of x processed per grid step. 128 rows x 512 features
# x 4 bytes = 256 KB per operand block — comfortably inside a 16 MB VMEM
# budget even for the widest ShadowHand layer (512x512 weights = 1 MB).
BLOCK_B = 128


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad2(a: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    r, c = a.shape
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


# ---------------------------------------------------------------------------
# Forward kernel: o = act(x @ w + b)
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One grid step: a (BLOCK_B, din) block of x against the full (din, dout)
    weight tile resident in VMEM; accumulate in f32 on the MXU."""
    x = x_ref[...]
    acc = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if activation == "tanh":
        acc = jnp.tanh(acc)
    o_ref[...] = acc


def _fwd_pallas(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, activation: str) -> jnp.ndarray:
    bsz, din = x.shape
    dout = w.shape[1]
    dinp, doutp = _pad_to(din, LANE), _pad_to(dout, LANE)
    bp = _pad_to(bsz, BLOCK_B)
    xp = _pad2(x, bp, dinp)
    wp = _pad2(w, dinp, doutp)
    bpd = jnp.pad(b, (0, doutp - dout))
    grid = (bp // BLOCK_B,)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, dinp), lambda i: (i, 0)),
            pl.BlockSpec((dinp, doutp), lambda i: (0, 0)),
            pl.BlockSpec((doutp,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, doutp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, doutp), jnp.float32),
        interpret=True,
    )(xp, wp, bpd)
    return out[:bsz, :dout]


# ---------------------------------------------------------------------------
# Backward kernels.
#
# dz = g * act'(y);  dx = dz @ w^T;  dw = x^T @ dz;  db = sum_rows(dz)
#
# dx is blocked over the batch grid like the forward pass. dw/db need a
# reduction over the whole batch: we accumulate across grid steps into the
# output block (grid-sequential accumulation — the standard Pallas reduction
# idiom; on TPU the grid is executed sequentially per core so this is safe,
# and interpret mode preserves those semantics).
# ---------------------------------------------------------------------------


def _bwd_dx_kernel(g_ref, y_ref, w_ref, dx_ref, *, activation: str):
    g = g_ref[...]
    if activation == "tanh":
        y = y_ref[...]
        g = g * (1.0 - y * y)
    dx_ref[...] = jnp.dot(g, w_ref[...].T, preferred_element_type=jnp.float32)


def _bwd_dw_kernel(x_ref, g_ref, y_ref, dw_ref, db_ref, *, activation: str):
    i = pl.program_id(0)
    g = g_ref[...]
    if activation == "tanh":
        y = y_ref[...]
        g = g * (1.0 - y * y)
    dw = jnp.dot(x_ref[...].T, g, preferred_element_type=jnp.float32)
    db = jnp.sum(g, axis=0)

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = dw
        db_ref[...] = db

    @pl.when(i != 0)
    def _acc():
        dw_ref[...] += dw
        db_ref[...] += db


def _bwd_pallas(x, w, y, g, activation: str):
    bsz, din = x.shape
    dout = w.shape[1]
    dinp, doutp = _pad_to(din, LANE), _pad_to(dout, LANE)
    bp = _pad_to(bsz, BLOCK_B)
    xp = _pad2(x, bp, dinp)
    wp = _pad2(w, dinp, doutp)
    yp = _pad2(y, bp, doutp)
    gp = _pad2(g, bp, doutp)
    grid = (bp // BLOCK_B,)

    dx = pl.pallas_call(
        functools.partial(_bwd_dx_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, doutp), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, doutp), lambda i: (i, 0)),
            pl.BlockSpec((dinp, doutp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, dinp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, dinp), jnp.float32),
        interpret=True,
    )(gp, yp, wp)

    dw, db = pl.pallas_call(
        functools.partial(_bwd_dw_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, dinp), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, doutp), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, doutp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((dinp, doutp), lambda i: (0, 0)),
            pl.BlockSpec((doutp,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dinp, doutp), jnp.float32),
            jax.ShapeDtypeStruct((doutp,), jnp.float32),
        ],
        interpret=True,
    )(xp, gp, yp)

    return dx[:bsz, :din], dw[:din, :dout], db[:dout]


# ---------------------------------------------------------------------------
# Public differentiable entry points.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation: str = "tanh"):
    """``act(x @ w + b)`` as a Pallas kernel, differentiable via custom_vjp.

    activation: "tanh" or "none".
    """
    return _fwd_pallas(x, w, b, activation)


def _fl_fwd(x, w, b, activation):
    y = _fwd_pallas(x, w, b, activation)
    return y, (x, w, y)


def _fl_bwd(activation, res, g):
    x, w, y = res
    dx, dw, db = _bwd_pallas(x, w, y, g, activation)
    return dx, dw, db


fused_linear.defvjp(_fl_fwd, _fl_bwd)


def mlp_forward(x, layers):
    """Run a full MLP: ``layers`` is a list of (w, b); tanh on all but the
    last layer, which is linear. Every layer is the Pallas fused kernel."""
    n = len(layers)
    for i, (w, b) in enumerate(layers):
        x = fused_linear(x, w, b, "tanh" if i < n - 1 else "none")
    return x


def vmem_footprint_bytes(din: int, dout: int, block_b: int = BLOCK_B) -> int:
    """Estimated VMEM bytes for one forward grid step (f32): the x block,
    the full weight tile, bias, and the output block. Used by the perf pass
    to validate block shapes against the 16 MB VMEM budget."""
    dinp, doutp = _pad_to(din, 128), _pad_to(dout, 128)  # TPU lanes
    return 4 * (block_b * dinp + dinp * doutp + doutp + block_b * doutp)


def mxu_utilization_estimate(din: int, dout: int) -> float:
    """Fraction of MXU work that is useful (un-padded) at 128-lane padding."""
    dinp, doutp = _pad_to(din, 128), _pad_to(dout, 128)
    return (din * dout) / float(dinp * doutp)
