"""Pure-jnp oracle for the Pallas kernels in fused_mlp.py.

This is the CORE correctness signal: pytest asserts the Pallas kernels
(forward values and custom-vjp gradients) match these reference
implementations to tight tolerances across a hypothesis-driven sweep of
shapes and dtypes.
"""

from __future__ import annotations

import jax.numpy as jnp


def fused_linear_ref(x, w, b, activation: str = "tanh"):
    y = x @ w + b[None, :]
    if activation == "tanh":
        y = jnp.tanh(y)
    return y


def mlp_forward_ref(x, layers):
    n = len(layers)
    for i, (w, b) in enumerate(layers):
        x = fused_linear_ref(x, w, b, "tanh" if i < n - 1 else "none")
    return x


def fused_linear_bwd_ref(x, w, b, g, activation: str = "tanh"):
    """Hand-derived VJP for act(x @ w + b); returns (dx, dw, db)."""
    z = x @ w + b[None, :]
    if activation == "tanh":
        y = jnp.tanh(z)
        g = g * (1.0 - y * y)
    dx = g @ w.T
    dw = x.T @ g
    db = g.sum(axis=0)
    return dx, dw, db
