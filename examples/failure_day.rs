//! Survive the cluster: a 16-GPU (8 nodes x 2) multi-tenant serving day
//! under seeded hardware failure injection. Three tenants — sync training,
//! a diurnal SLO serving fleet, and a late-arriving A3C pipeline — co-run
//! on one shared fabric while a deterministic fault trace (seeded
//! generator merged with a declarative schedule) kills GPUs, whole nodes,
//! and the NVSwitch out from under them. The scheduler checkpoints every
//! running tenant on a fixed cadence (capture cost charged to the
//! tenant's own executor clocks), kills tenants whose members' GPUs die,
//! re-admits them onto surviving capacity resumed from their last
//! checkpoint, and replans collectives around dead links.
//!
//! Asserted, not just printed:
//!   - the faulted day is bit-reproducible: two runs of the same seed
//!     produce identical timelines and identical metric bits;
//!   - at least one tenant is killed, and EVERY killed tenant is
//!     re-admitted and runs to completion;
//!   - goodput lost to kills is bounded by one checkpoint interval (plus
//!     a round of slack) of whole-cluster service per kill;
//!   - the failure-free baseline of the same day records zero kills and
//!     zero lost goodput.
//!
//!     cargo run --release --example failure_day -- [bench]

use anyhow::Result;

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::drl::a3c::AsyncConfig;
use gmi_drl::fault::{FaultPlan, FaultTrace, FaultTraceConfig};
use gmi_drl::sched::{
    corun_scenario, run_cluster, sched_table, ClusterRunResult, JobSpec, SchedAction, SchedConfig,
};
use gmi_drl::vtime::CostModel;

const NODES: usize = 8;
const GPUS_PER_NODE: usize = 2;
const DAY_S: f64 = 0.5;
const SEED: u64 = 11;
const CKPT_S: f64 = 0.05;

/// A guaranteed backbone of hardware events on top of the seeded stream,
/// in the same declarative format `--fault-trace` files use.
const SCRIPTED: &str = "\
# mid-morning single-GPU loss, repaired after 0.1s
0.10 fail gpu 3
0.20 repair gpu 3
# early-afternoon whole-node loss (GPUs 8-9), never repaired
0.28 fail node 4
# brief NVSwitch outage: collectives must reroute over host links
0.33 fail nvswitch
0.38 repair nvswitch
";

/// Everything that must be bit-identical across two runs of the same
/// seed. `{:?}` on f64 prints the shortest round-trip form, so equal
/// strings mean equal bits.
fn fingerprint(r: &ClusterRunResult) -> Vec<String> {
    let mut out = Vec::new();
    for e in &r.events {
        out.push(format!(
            "{:?} {} {} {} {:?} {}",
            e.t_s, e.job, e.action, e.members, e.share, e.detail
        ));
    }
    for j in &r.jobs {
        out.push(format!(
            "job {}: rate {:?} span {:?} busy {:?} kills {} lost {:?} recov {:?} ckpt {:?}",
            j.id,
            j.metrics.steps_per_sec,
            j.metrics.span_s,
            j.busy_s,
            j.kills,
            j.goodput_lost_s,
            j.recovery_s,
            j.checkpoint_s,
        ));
    }
    out.push(format!(
        "cluster: makespan {:?} util {:?} lost {:?} faults {}",
        r.makespan_s, r.cluster_utilization, r.goodput_lost_s, r.fault_events
    ));
    out
}

fn main() -> Result<()> {
    let abbr = std::env::args().nth(1).unwrap_or_else(|| "AT".into());
    let bench = static_registry()
        .get(&abbr)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark {abbr}"))?;
    let cost = CostModel::new(&bench);
    let topo = Topology::flat_cluster(NODES, GPUS_PER_NODE);
    let gpus = topo.num_gpus();

    // The tenant mix: the canonical train + serve co-run plus an A3C
    // pipeline arriving 20% into the day.
    let mut jobs = corun_scenario(&topo, &bench, &cost, DAY_S, SEED, false);
    jobs.push(JobSpec::a3c(
        2,
        "train-a3c",
        2,
        0.2 * DAY_S,
        (1, 1),
        0.3,
        0.1,
        1024,
        AsyncConfig { rounds: 8, batch_samples: 4096, ..AsyncConfig::default() },
    ));

    // The failure schedule: a seeded generator stream (GPU and NVSwitch
    // classes; the scripted backbone already covers whole-node loss)
    // merged with the scripted events above. Generated failures repair
    // quickly, so the permanent capacity loss is the scripted node alone
    // and the surviving cluster always has room to re-admit every tenant.
    let generated = FaultTrace::generate(&FaultTraceConfig {
        seed: SEED,
        duration_s: 0.6 * DAY_S,
        num_gpus: gpus,
        gpus_per_node: GPUS_PER_NODE,
        gpu_mtbf_s: 0.3,
        node_mtbf_s: f64::INFINITY,
        link_mtbf_s: 0.45,
        repair_after_s: Some(0.04),
    });
    let mut events = generated.events;
    events.extend(FaultTrace::parse(SCRIPTED, GPUS_PER_NODE)?.events);
    let trace = FaultTrace::new(events, GPUS_PER_NODE);

    println!(
        "{} failure day: {gpus} GPUs ({NODES} nodes x {GPUS_PER_NODE}), {} tenants, \
         {DAY_S:.1}s day, checkpoint every {CKPT_S}s (seed {SEED})\n",
        bench.name,
        jobs.len(),
    );
    println!("hardware event schedule ({} events):", trace.events.len());
    print!("{}", trace.to_text());

    let faulted_cfg = SchedConfig {
        faults: Some(FaultPlan::new(trace).with_checkpoint_interval(CKPT_S)),
        ..SchedConfig::default()
    };
    let clean_cfg = SchedConfig::default();

    let r = run_cluster(&topo, &bench, &cost, &jobs, &faulted_cfg)?;
    let rerun = run_cluster(&topo, &bench, &cost, &jobs, &faulted_cfg)?;
    let clean = run_cluster(&topo, &bench, &cost, &jobs, &clean_cfg)?;

    // Bit-reproducibility: same seed, same day, down to the float bits.
    assert_eq!(
        fingerprint(&r),
        fingerprint(&rerun),
        "faulted day is not bit-reproducible"
    );

    println!("\nper-job outcome (faulted day):");
    r.job_table().print();

    // The failure story, without the routine grow/shrink noise.
    let story: Vec<_> = r
        .events
        .iter()
        .filter(|e| {
            matches!(
                e.action,
                SchedAction::Fail
                    | SchedAction::Repair
                    | SchedAction::Kill
                    | SchedAction::Checkpoint
                    | SchedAction::Admit
            )
        })
        .cloned()
        .collect();
    println!("\nfailure / recovery timeline:");
    sched_table(&story).print();

    let total_kills: usize = r.jobs.iter().map(|j| j.kills).sum();
    let total_ckpt_s: f64 = r.jobs.iter().map(|j| j.checkpoint_s).sum();
    let total_recov_s: f64 = r.jobs.iter().map(|j| j.recovery_s).sum();
    assert!(r.fault_events > 0, "no hardware events were applied");
    assert!(total_kills >= 1, "the scripted GPU losses must kill at least one tenant");
    for j in &r.jobs {
        assert!(
            j.completed_s > 0.0,
            "tenant {} ({}) never completed — a killed tenant was not re-admitted",
            j.id,
            j.name
        );
    }
    // Every kill is followed by a re-admission of the same tenant.
    for (i, e) in r.events.iter().enumerate() {
        if e.action == SchedAction::Kill {
            assert!(
                r.events[i..]
                    .iter()
                    .any(|a| a.action == SchedAction::Admit && a.job == e.job),
                "job {} was killed at t={:.3} and never re-admitted",
                e.job,
                e.t_s
            );
        }
    }
    // Checkpointing bounds the blast radius: each kill discards at most
    // one checkpoint interval (plus one scheduling round of slack) of
    // whole-cluster service.
    let bound = total_kills as f64 * (CKPT_S + faulted_cfg.quantum_s) * gpus as f64;
    assert!(
        r.goodput_lost_s <= bound + 1e-9,
        "goodput loss {:.4} GPU-s exceeds the checkpoint bound {:.4}",
        r.goodput_lost_s,
        bound
    );
    // The failure-free control: same day, nothing lost.
    assert_eq!(clean.fault_events, 0);
    assert!(clean.jobs.iter().all(|j| j.kills == 0));
    assert!(clean.goodput_lost_s == 0.0);

    println!(
        "\n{} hardware events | {} kill(s) | goodput lost {:.3} GPU-s (bound {:.3}) | \
         recovery {:.3}s total | checkpoint overhead {:.3} GPU-s",
        r.fault_events, total_kills, r.goodput_lost_s, bound, total_recov_s, total_ckpt_s,
    );
    println!(
        "failure-free baseline: makespan {:.2}s vs faulted {:.2}s | util {:.1}% vs {:.1}% | \
         0 kills, 0.000 GPU-s lost",
        clean.makespan_s,
        r.makespan_s,
        100.0 * clean.cluster_utilization,
        100.0 * r.cluster_utilization,
    );
    println!("\nfaulted day reproduced bit-for-bit across two runs; all tenants finished.");
    Ok(())
}
