//! Workload-aware GMI selection (Algorithm 2) across all six paper
//! benchmarks: prints the full profiling trace for one benchmark and the
//! selected configuration for every benchmark at 1/2/4/8 GPUs.
//!
//!     cargo run --release --example gmi_search

use gmi_drl::config::{static_registry, PAPER_BENCHMARKS};
use gmi_drl::gmi::GmiBackend;
use gmi_drl::metrics::{fmt_rate, Table};
use gmi_drl::selection;
use gmi_drl::vtime::CostModel;

fn main() {
    let reg = static_registry();

    // Full trace for Ant on 4 GPUs.
    let at = &reg["AT"];
    let cost = CostModel::new(at);
    let (_, trace) = selection::explore(at, &cost, GmiBackend::Mps, 4, at.horizon);
    println!("Algorithm 2 trace for AT on 4 GPUs ({} points profiled):", trace.len());
    let mut t = Table::new(&["GMI/GPU", "num_env", "runnable", "steps/s", "mem GiB"]);
    for p in trace.iter().filter(|p| p.gmi_per_gpu <= 4) {
        t.row(vec![
            p.gmi_per_gpu.to_string(),
            p.num_env.to_string(),
            if p.runnable { "yes".into() } else { "NO".into() },
            fmt_rate(p.top),
            format!("{:.1}", p.mem_gib),
        ]);
    }
    t.print();

    // Selected configuration per benchmark per GPU count.
    println!("\nSelected configurations (GMIperGPU / num_env / projected steps/s):");
    let mut t = Table::new(&["Bench", "1 GPU", "2 GPUs", "4 GPUs", "8 GPUs"]);
    for abbr in PAPER_BENCHMARKS {
        let b = &reg[abbr];
        let cost = CostModel::new(b);
        let mut row = vec![abbr.to_string()];
        for gpus in [1usize, 2, 4, 8] {
            let (sel, _) = selection::explore(b, &cost, GmiBackend::Mps, gpus, b.horizon);
            row.push(match sel {
                Some(s) => format!(
                    "{}x{} -> {}",
                    s.gmi_per_gpu,
                    s.num_env,
                    fmt_rate(s.projected_top)
                ),
                None => "-".to_string(),
            });
        }
        t.row(row);
    }
    t.print();
}
