//! SLO-aware serving gateway over a diurnal day: the same seeded arrival
//! trace replayed against (a) a statically provisioned GMI fleet and
//! (b) the elastic fleet driven by the SLO autoscaler — with the scaling
//! timeline the autoscaler produced. The open-loop successor of the
//! Fig 7(a) serving scenario.
//!
//!     cargo run --release --example serving_fleet -- [bench]

use anyhow::Result;

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::mapping::build_gateway_fleet;
use gmi_drl::metrics::{fmt_rate, Table};
use gmi_drl::serve::{
    batch_seconds, generate_trace, run_gateway, scale_table, AutoscaleConfig, GatewayConfig,
    TrafficPattern,
};
use gmi_drl::vtime::CostModel;

const MAX_BATCH: usize = 32;
const INITIAL_PER_GPU: usize = 1;
const MAX_PER_GPU: usize = 4;
const GPUS: usize = 2;
const DAY_S: f64 = 1.0;

fn main() -> Result<()> {
    let abbr = std::env::args().nth(1).unwrap_or_else(|| "AT".into());
    let bench = static_registry()
        .get(&abbr)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark {abbr}"))?;
    let cost = CostModel::new(&bench);
    let topo = Topology::dgx_a100(GPUS);

    // Fleet: 1 GMI/GPU initially, validated headroom for 4/GPU.
    let share = (100.0 / MAX_PER_GPU as f64).floor() / 100.0;
    let gmi_rate = MAX_BATCH as f64 / batch_seconds(&bench, &cost, &topo, share, MAX_BATCH);
    let static_capacity = gmi_rate * (GPUS * INITIAL_PER_GPU) as f64;

    // One virtual day compressed into a second: trough at 25% of the
    // static fleet's capacity, peak at 2.2x (the fleet must grow or blow
    // its SLO).
    let trough = 0.25 * static_capacity;
    let peak = 2.2 * static_capacity;
    let pattern = TrafficPattern::Diurnal { base: trough, peak, period_s: DAY_S };
    let trace = generate_trace(&pattern, DAY_S, 7, 16);
    println!(
        "{} diurnal day: {} requests over {DAY_S:.1}s (trough {} req/s, peak {} req/s)\n",
        bench.name,
        fmt_rate(trace.len() as f64),
        fmt_rate(trough),
        fmt_rate(peak),
    );

    let slo_s = 10e-3;
    let base_cfg = GatewayConfig {
        max_batch: MAX_BATCH,
        max_wait_s: 1e-3,
        admission_cap: None,
        slo_s,
        autoscale: None,
        ..GatewayConfig::default()
    };
    let static_fleet = build_gateway_fleet(&topo, INITIAL_PER_GPU, MAX_PER_GPU, MAX_BATCH, &cost, None)?;
    let static_run = run_gateway(&static_fleet, &bench, &cost, &trace, &base_cfg)?;

    let mut elastic_cfg = base_cfg.clone();
    elastic_cfg.autoscale = Some(AutoscaleConfig {
        window_s: 0.025,
        slo_p99_s: slo_s,
        min_fleet: GPUS, // never below one GMI per GPU
        max_per_gpu: MAX_PER_GPU,
        ..AutoscaleConfig::default()
    });
    let elastic_fleet =
        build_gateway_fleet(&topo, INITIAL_PER_GPU, MAX_PER_GPU, MAX_BATCH, &cost, None)?;
    let elastic_run = run_gateway(&elastic_fleet, &bench, &cost, &trace, &elastic_cfg)?;

    let mut t = Table::new(&["fleet", "p50 (ms)", "p95 (ms)", "p99 (ms)", "SLO att.", "served"]);
    for (name, r) in [("static", &static_run), ("autoscaled", &elastic_run)] {
        t.row(vec![
            name.to_string(),
            format!("{:.2}", r.latency.p50_s * 1e3),
            format!("{:.2}", r.latency.p95_s * 1e3),
            format!("{:.2}", r.latency.p99_s * 1e3),
            format!("{:.1}%", 100.0 * r.latency.attainment),
            fmt_rate(r.latency.served as f64),
        ]);
    }
    t.print();

    println!("\nscaling timeline (autoscaled fleet):");
    scale_table(&elastic_run.scale_events).print();

    let grows = elastic_run
        .scale_events
        .iter()
        .filter(|e| e.action == gmi_drl::serve::ScaleAction::Grow)
        .count();
    let shrinks = elastic_run.scale_events.len() - grows;
    println!(
        "\n{} grow / {} shrink events; batch histogram (autoscaled): {:?}",
        grows,
        shrinks,
        elastic_run.batch_histogram(),
    );
    Ok(())
}
