//! Multi-GPU DRL serving fleet: GMI-based serving (MIG-backed TCG blocks)
//! vs the Isaac-Gym-style one-process-per-GPU baseline, across GPU counts —
//! the Fig 7(a) scenario as a runnable application.
//!
//!     cargo run --release --example serving_fleet -- [bench] [--real]

use anyhow::Result;

use gmi_drl::baselines;
use gmi_drl::cluster::Topology;
use gmi_drl::config::{artifacts_dir, static_registry};
use gmi_drl::drl::serving::{run_serving, ServingConfig};
use gmi_drl::drl::Compute;
use gmi_drl::gmi::GmiBackend;
use gmi_drl::mapping::{build_serving_layout, MappingTemplate};
use gmi_drl::metrics::{fmt_rate, Table};
use gmi_drl::runtime::ExecServer;
use gmi_drl::selection;
use gmi_drl::vtime::CostModel;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let abbr = args.get(1).filter(|s| !s.starts_with("--")).cloned().unwrap_or("AT".into());
    let real = args.iter().any(|a| a == "--real");

    let bench = static_registry()
        .get(&abbr)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark {abbr}"))?;
    let cost = CostModel::new(&bench);

    let (_server, compute);
    if real {
        let s = ExecServer::start(artifacts_dir())?;
        compute = Compute::Real { handle: s.handle() };
        _server = Some(s);
    } else {
        compute = Compute::Null;
        _server = None;
    }

    println!("serving fleet for {} ({})\n", bench.name, abbr);
    let mut t = Table::new(&[
        "GPUs",
        "GMI steps/s",
        "GMI util",
        "baseline steps/s",
        "baseline util",
        "speedup",
    ]);
    for gpus in [1usize, 2, 4, 8] {
        let topo = Topology::dgx_a100(gpus);
        let (sel, _) = selection::explore(&bench, &cost, GmiBackend::Mig, gpus, bench.horizon);
        let sel = sel.expect("no config");
        let layout = build_serving_layout(
            &topo,
            MappingTemplate::TaskColocated,
            sel.gmi_per_gpu,
            sel.num_env,
            &cost,
            None, // auto: MIG for serving on A100 (§3)
        )?;
        let cfg = ServingConfig { rounds: 10, seed: 1, real_replicas: 1 };
        let ours = run_serving(&layout, &bench, &cost, &compute, &cfg)?;
        let base = baselines::isaac_serving(
            &topo,
            &bench,
            &cost,
            &compute,
            sel.num_env * sel.gmi_per_gpu,
            10,
        )?;
        t.row(vec![
            gpus.to_string(),
            fmt_rate(ours.steps_per_sec),
            format!("{:.0}%", 100.0 * ours.utilization),
            fmt_rate(base.steps_per_sec),
            format!("{:.0}%", 100.0 * base.utilization),
            format!("{:.2}x", ours.steps_per_sec / base.steps_per_sec),
        ]);
    }
    t.print();
    println!("\n(backend: MIG serving blocks — the paper's §3 auto-selection)");
    Ok(())
}
