//! One shared cluster, three tenants: a sync-training job (low priority)
//! co-runs with a diurnal SLO serving fleet (high priority) under the
//! preemptive multi-tenant scheduler, against the classic static
//! partitioning baseline (each tenant pinned to its own GPU half) over
//! the SAME seeded trace and the same total simulated environments.
//! Mid-day an A3C training tenant (agents + compressor channels +
//! trainers — a Workload program like every other tenant) joins the
//! preemptive schedule; the static partition has no spare slice for it
//! at all. Prints the preemption timeline and the head-to-head
//! comparison: the preemptive schedule must win on BOTH training
//! throughput and serving p99 (asserted, like the paper's co-location
//! claims, in `rust/tests/prop_sched.rs`).
//!
//!     cargo run --release --example shared_cluster -- [bench]

use anyhow::Result;

use gmi_drl::cluster::Topology;
use gmi_drl::config::static_registry;
use gmi_drl::drl::a3c::AsyncConfig;
use gmi_drl::metrics::{fmt_rate, Table};
use gmi_drl::sched::{
    corun_scenario, run_cluster, sched_table, JobSpec, SchedAction, SchedConfig,
};
use gmi_drl::vtime::CostModel;

const GPUS: usize = 2;
const DAY_S: f64 = 1.0;
const SEED: u64 = 7;

fn main() -> Result<()> {
    let abbr = std::env::args().nth(1).unwrap_or_else(|| "AT".into());
    let bench = static_registry()
        .get(&abbr)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark {abbr}"))?;
    let cost = CostModel::new(&bench);
    let topo = Topology::dgx_a100(GPUS);

    // Static partitioning: training owns GPU 0 exclusively, the serving
    // fleet owns GPU 1 at fixed size. Preemptive: both tenants share both
    // GPUs; the scheduler reclaims training share at the diurnal peak and
    // gives it back at the trough.
    let static_jobs = corun_scenario(&topo, &bench, &cost, DAY_S, SEED, true);
    let mut elastic_jobs = corun_scenario(&topo, &bench, &cost, DAY_S, SEED, false);
    // A third tenant only the preemptive schedule can absorb: an A3C
    // training job (1 agent + 1 trainer over the compressor channels)
    // arriving 20% into the day. The static partition's slices are full,
    // so it has no home there — scenario diversity the Workload-program
    // scheduler unlocked.
    elastic_jobs.push(JobSpec::a3c(
        2,
        "train-a3c",
        2,
        0.2 * DAY_S,
        (1, 1),
        0.3,
        0.1,
        1024,
        AsyncConfig { rounds: 8, batch_samples: 4096, ..AsyncConfig::default() },
    ));
    let static_cfg = SchedConfig { preemptive: false, ..SchedConfig::default() };
    let elastic_cfg = SchedConfig::default();

    println!(
        "{} shared cluster, {GPUS} GPUs, one {DAY_S:.1}s serving day (seed {SEED})\n",
        bench.name
    );
    let stat = run_cluster(&topo, &bench, &cost, &static_jobs, &static_cfg)?;
    let elas = run_cluster(&topo, &bench, &cost, &elastic_jobs, &elastic_cfg)?;

    let mut t = Table::new(&[
        "schedule",
        "train steps/s",
        "serve p99 (ms)",
        "SLO att.",
        "cluster util",
        "fairness",
    ]);
    for (name, r) in [("static partition", &stat), ("preemptive", &elas)] {
        let train = r.job(0).expect("training report");
        let serve = r.job(1).expect("serving report");
        let lat = serve.metrics.latency.as_ref().expect("serving latency");
        t.row(vec![
            name.to_string(),
            fmt_rate(train.metrics.steps_per_sec),
            format!("{:.2}", lat.p99_s * 1e3),
            format!("{:.1}%", 100.0 * lat.attainment),
            format!("{:.1}%", 100.0 * r.cluster_utilization),
            format!("{:.3}", r.fairness),
        ]);
    }
    t.print();

    let a3c = elas.job(2).expect("a3c report");
    println!(
        "\na3c tenant (preemptive only): {} preds/s | ttop {} | waited {:.1}ms | \
         {} preemption(s)",
        fmt_rate(a3c.metrics.pps),
        fmt_rate(a3c.metrics.ttop),
        a3c.wait_s * 1e3,
        a3c.preemptions,
    );

    println!("\npreemption timeline (preemptive schedule):");
    sched_table(&elas.events).print();

    let count = |a: SchedAction| elas.events.iter().filter(|e| e.action == a).count();
    println!(
        "\n{} preempt / {} evict / {} grow / {} shrink / {} restore events; \
         training lost {:.1}ms to cross-job interference",
        count(SchedAction::Preempt),
        count(SchedAction::Evict),
        count(SchedAction::Grow),
        count(SchedAction::Shrink),
        count(SchedAction::Restore),
        elas.job(0).expect("training report").xjob_interference_s * 1e3,
    );
    Ok(())
}
