//! Quickstart: the smallest end-to-end GMI-DRL run with REAL numerics.
//!
//! Loads the AOT artifacts (run `make artifacts` first), asks Algorithm 2
//! for a configuration, builds a TCG_EX layout on 2 simulated A100s, and
//! trains BallBalance PPO for a handful of iterations through the PJRT CPU
//! client — printing the loss and reward as it goes.
//!
//!     cargo run --release --example quickstart

use anyhow::Result;

use gmi_drl::cluster::Topology;
use gmi_drl::config::artifacts_dir;
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::gmi::GmiBackend;
use gmi_drl::mapping::{build_sync_layout, MappingTemplate};
use gmi_drl::runtime::ExecServer;
use gmi_drl::selection;
use gmi_drl::vtime::CostModel;
use gmi_drl::Manifest;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    let bench = manifest.bench("BB")?.clone();
    println!(
        "benchmark: {} ({}), obs {} act {} params {}",
        bench.name, bench.abbr, bench.obs_dim, bench.act_dim, bench.num_params
    );

    // 1. Workload-aware GMI selection (Algorithm 2).
    let cost = CostModel::new(&bench);
    let (sel, _) = selection::explore(&bench, &cost, GmiBackend::Mps, 2, bench.horizon);
    let sel = sel.expect("no runnable configuration");
    println!(
        "Algorithm 2 picked: GMIperGPU={} num_env={} (projected {:.0} steps/s)",
        sel.gmi_per_gpu, sel.num_env, sel.projected_top
    );

    // 2. Task-aware GMI mapping: holistic training GMIs (TCG_EX).
    let topo = Topology::dgx_a100(2);
    let layout = build_sync_layout(
        &topo,
        MappingTemplate::TaskColocated,
        sel.gmi_per_gpu,
        sel.num_env,
        &cost,
        None,
    )?;
    println!(
        "layout: {} GMIs on {} GPUs, backend {}",
        layout.rollout_gmis.len(),
        topo.num_gpus(),
        layout.backend_name()
    );

    // 3. Real training through the PJRT runtime.
    let server = ExecServer::start(dir)?;
    let compute = Compute::Real { handle: server.handle() };
    let cfg = SyncConfig { iterations: 8, real_replicas: 1, ..Default::default() };
    let r = run_sync(&layout, &bench, &cost, &compute, &cfg)?;

    println!("\niter |    loss | pi_loss |  v_loss | reward");
    for (i, s) in r.stats_per_iter.iter().enumerate() {
        println!(
            "{:>4} | {:>7.4} | {:>7.4} | {:>7.4} | {:>6.3}",
            i, s.loss, s.pi_loss, s.v_loss, s.mean_reward
        );
    }
    r.metrics.print_summary(&format!("quickstart BB [{}]", r.strategy));
    let (execs, compile_s, exec_s, _, _) = server.handle().stats().snapshot();
    println!("PJRT: {execs} executions, {compile_s:.1}s compiling, {exec_s:.1}s executing");
    Ok(())
}
