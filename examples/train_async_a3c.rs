//! Asynchronized DRL training (A3C) with channel-based experience sharing,
//! real numerics: decoupled serving/training GPUs (Fig 6b), the
//! dispenser -> compressor -> migrator -> batcher pipeline, and a UCC vs
//! MCC comparison on the same workload (Table 8's setting, small scale).
//!
//!     cargo run --release --example train_async_a3c -- [rounds] [bench]

use anyhow::Result;

use gmi_drl::channels::ShareMode;
use gmi_drl::cluster::Topology;
use gmi_drl::config::artifacts_dir;
use gmi_drl::drl::a3c::{run_async, AsyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::mapping::build_async_layout;
use gmi_drl::metrics::{fmt_rate, Table};
use gmi_drl::runtime::ExecServer;
use gmi_drl::vtime::CostModel;
use gmi_drl::Manifest;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rounds: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let abbr = args.get(2).cloned().unwrap_or_else(|| "AY".to_string());

    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    let bench = manifest.bench(&abbr)?.clone();
    let cost = CostModel::new(&bench);

    // 2 serving GPUs (3 agent GMIs each) + 2 training GPUs (2 trainers each).
    let topo = Topology::dgx_a100(4);
    let layout = build_async_layout(&topo, 2, 3, 2, 2048, &cost)?;
    println!(
        "async layout: {} agent GMIs on GPUs 0-1, {} trainer GMIs on GPUs 2-3",
        layout.rollout_gmis.len(),
        layout.trainer_gmis.len()
    );

    let server = ExecServer::start(dir)?;
    let compute = Compute::Real { handle: server.handle() };

    let mut table = Table::new(&["mode", "PPS", "TTOP", "updates", "packets", "mean pkt KiB"]);
    for (name, mode) in [("UCC", ShareMode::UniChannel), ("MCC", ShareMode::MultiChannel)] {
        let cfg = AsyncConfig {
            rounds,
            seed: 3,
            share_mode: mode,
            batch_samples: 8192,
            param_sync_every: 4,
            lr: 3e-4,
            real_replicas: 1,
            ..Default::default()
        };
        let r = run_async(&layout, &bench, &cost, &compute, &cfg)?;
        table.row(vec![
            name.to_string(),
            fmt_rate(r.metrics.pps),
            fmt_rate(r.metrics.ttop),
            r.updates.to_string(),
            r.channel_stats.packets_out.to_string(),
            format!("{:.0}", r.channel_stats.mean_packet_bytes() / 1024.0),
        ]);
        println!(
            "{name}: reward {:.4} | span {:.2}s | transfer {:.3}s",
            r.metrics.final_reward, r.metrics.span_s, r.channel_stats.transfer_seconds
        );
    }
    println!();
    table.print();
    println!("\n(MCC should move the same bytes in fewer, larger packets -> higher TTOP)");
    Ok(())
}
