//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Trains a real PPO policy on the Ant benchmark through the full stack —
//! Pallas-kernel policy forward/backward (L1), JAX-lowered HLO artifacts
//! (L2), rust GMI coordinator with layout-aware gradient reduction (L3) —
//! for a few hundred iterations, logging the loss/reward curve and writing
//! it to `e2e_reward_curve.csv`. Exits non-zero if learning did not happen
//! (final-quarter reward must beat the first-quarter reward).
//!
//!     cargo run --release --example train_sync_e2e -- [iters] [bench]

use std::io::Write;

use anyhow::Result;

use gmi_drl::cluster::Topology;
use gmi_drl::config::artifacts_dir;
use gmi_drl::drl::sync::{run_sync, SyncConfig};
use gmi_drl::drl::Compute;
use gmi_drl::mapping::{build_sync_layout, MappingTemplate};
use gmi_drl::runtime::ExecServer;
use gmi_drl::vtime::CostModel;
use gmi_drl::Manifest;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let iters: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let abbr = args.get(2).cloned().unwrap_or_else(|| "AT".to_string());

    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    let bench = manifest.bench(&abbr)?.clone();
    let cost = CostModel::new(&bench);
    println!(
        "e2e: training {} ({} params, {} envs x {} steps/iter) for {} iterations",
        bench.name, bench.num_params, bench.num_env, bench.horizon, iters
    );

    // 2 GPUs x 2 holistic GMIs -> MRR gradient reduction by Algorithm 1.
    let topo = Topology::dgx_a100(2);
    let layout =
        build_sync_layout(&topo, MappingTemplate::TaskColocated, 2, bench.num_env, &cost, None)?;
    let server = ExecServer::start(dir)?;
    let compute = Compute::Real { handle: server.handle() };

    let cfg = SyncConfig {
        iterations: iters,
        ppo_epochs: 2,
        minibatches: 4,
        lr: 1e-3,
        seed: 7,
        real_replicas: 1,
        strategy_override: None,
        elastic: None,
        overlap: true,
    };
    let t0 = std::time::Instant::now();
    let r = run_sync(&layout, &bench, &cost, &compute, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    // Loss/reward curve.
    let mut csv = String::from("iter,loss,pi_loss,v_loss,entropy,kl,reward\n");
    for (i, s) in r.stats_per_iter.iter().enumerate() {
        csv.push_str(&format!(
            "{},{},{},{},{},{},{}\n",
            i, s.loss, s.pi_loss, s.v_loss, s.entropy, s.approx_kl, s.mean_reward
        ));
        if i % (iters / 20).max(1) == 0 {
            println!(
                "iter {:>4}: loss {:>8.4} | v_loss {:>8.4} | kl {:>8.5} | reward {:>7.4}",
                i, s.loss, s.v_loss, s.approx_kl, s.mean_reward
            );
        }
    }
    let mut f = std::fs::File::create("e2e_reward_curve.csv")?;
    f.write_all(csv.as_bytes())?;
    println!("wrote e2e_reward_curve.csv ({} rows)", r.stats_per_iter.len());

    r.metrics.print_summary(&format!("e2e {abbr} [{}]", r.strategy));
    println!("wall-clock: {wall:.1}s for {iters} iterations");

    // Learning check: mean reward of the last quarter vs the first quarter.
    let n = r.stats_per_iter.len();
    let q = (n / 4).max(1);
    let first: f32 =
        r.stats_per_iter[..q].iter().map(|s| s.mean_reward).sum::<f32>() / q as f32;
    let last: f32 = r.stats_per_iter[n - q..].iter().map(|s| s.mean_reward).sum::<f32>()
        / q as f32;
    println!("reward first quarter {first:.4} -> last quarter {last:.4}");
    if last <= first {
        eprintln!("E2E FAILED: no reward improvement");
        std::process::exit(1);
    }
    println!("E2E OK: policy learned (+{:.4} reward)", last - first);
    Ok(())
}
